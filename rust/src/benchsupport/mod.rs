//! Shared plumbing for the paper-reproduction benches (rust/benches/*).
//!
//! Benches are plain `harness = false` mains (criterion is not in the
//! offline registry); each regenerates one table/figure. This module keeps
//! them short: corpus/checkpoint caching, in-process serving runs, and a
//! tiny table printer.

use crate::ckpt::Checkpoint;
use crate::coordinator::engine::{self, CacheScheme, EngineConfig, KvLayout};
use crate::coordinator::metrics::MetricsCollector;
use crate::coordinator::request::{Event, SubmitReq};
use crate::data::corpus::standard_corpus;
use crate::data::dataset::PackedDataset;
use crate::data::workload::{self, WorkloadSpec};
use crate::quant::{quantize_checkpoint, QuantConfig};
use crate::tokenizer::Tokenizer;
use crate::train::{TrainReport, Trainer};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::mpsc::channel;
use std::time::Instant;

/// Steps used when a bench needs a trained model. Override with
/// AO_BENCH_STEPS; the default keeps every bench minutes-scale on 1 core.
pub fn bench_steps(default: usize) -> usize {
    crate::util::env::var("AO_BENCH_STEPS")
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn corpus_pair() -> (String, String) {
    let train_p = crate::runs_dir().join("corpus_train.txt");
    let val_p = crate::runs_dir().join("corpus_val.txt");
    if train_p.exists() && val_p.exists() {
        (
            std::fs::read_to_string(&train_p).unwrap(),
            std::fs::read_to_string(&val_p).unwrap(),
        )
    } else {
        let c = standard_corpus(7, 512 * 1024, 64 * 1024);
        let _ = std::fs::write(&train_p, &c.train);
        let _ = std::fs::write(&val_p, &c.val);
        (c.train, c.val)
    }
}

/// Train (or reuse a cached) checkpoint for (model, recipe, steps).
pub fn trained_ckpt(
    model: &str,
    recipe: &str,
    steps: usize,
) -> Result<(PathBuf, Option<TrainReport>)> {
    let path = crate::runs_dir()
        .join(format!("bench_{model}_{recipe}_{steps}.aockpt"));
    if path.exists() {
        return Ok((path, None));
    }
    let (train_text, _) = corpus_pair();
    let tok = Tokenizer::byte_level();
    let mut trainer =
        Trainer::new(&crate::default_artifacts_dir(), model, recipe, 0)?;
    let ds = PackedDataset::from_text(&tok, &train_text, trainer.seq());
    let report = trainer.run(&ds, steps, 0xA0, |i, loss, _| {
        if i % 20 == 0 {
            eprintln!("  [{model}/{recipe}] step {i} loss {loss:.3}");
        }
    })?;
    trainer.export_checkpoint()?.save(&path)?;
    Ok((path, Some(report)))
}

/// Quantize a master ckpt into runs/ (cached) and return its path + sizes.
pub fn quantized_ckpt(
    master_path: &Path,
    tag: &str,
) -> Result<(PathBuf, crate::quant::SizeReport)> {
    let cfg = QuantConfig::parse(tag)?;
    let master = Checkpoint::load(master_path)?;
    let (packed, report) = quantize_checkpoint(&master, cfg)?;
    let stem = master_path.file_stem().unwrap().to_str().unwrap();
    let path = crate::runs_dir().join(format!("{stem}_{tag}.aockpt"));
    packed.save(&path)?;
    Ok((path, report))
}

/// Parse an optional AO_KV_CACHE value (None/"" -> f32 default). Split
/// from the env read so the error contract — name the variable, list the
/// valid values, exit non-zero through the bench's `?` — is unit-testable.
pub fn cache_scheme_from(var: Option<&str>) -> Result<CacheScheme> {
    match var {
        Some(v) if !v.is_empty() => {
            CacheScheme::parse(v).context("AO_KV_CACHE")
        }
        _ => Ok(CacheScheme::F32),
    }
}

/// Parse an optional AO_KV_LAYOUT value (None/"" -> static default).
pub fn kv_layout_from(var: Option<&str>) -> Result<KvLayout> {
    match var {
        Some(v) if !v.is_empty() => {
            KvLayout::parse(v).context("AO_KV_LAYOUT")
        }
        _ => Ok(KvLayout::Static),
    }
}

/// KV-cache scheme benches serve with: AO_KV_CACHE (f32 default).
pub fn bench_cache_scheme() -> Result<CacheScheme> {
    cache_scheme_from(crate::util::env::var("AO_KV_CACHE").as_deref())
}

/// KV-cache layout benches serve with: AO_KV_LAYOUT (static default).
pub fn bench_kv_layout() -> Result<KvLayout> {
    kv_layout_from(crate::util::env::var("AO_KV_LAYOUT").as_deref())
}

/// Parse an optional AO_PREFIX_CACHE value (None/"" -> enabled: the
/// prefix cache is a paged-layout no-op unless suffix artifacts exist).
pub fn prefix_cache_from(var: Option<&str>) -> Result<bool> {
    match var {
        Some("0") => Ok(false),
        Some("1") | Some("") | None => Ok(true),
        Some(other) => anyhow::bail!(
            "AO_PREFIX_CACHE: unknown value '{other}' (valid values: 0, 1)"
        ),
    }
}

/// Prefix-cache toggle benches serve with: AO_PREFIX_CACHE (on default).
pub fn bench_prefix_cache() -> Result<bool> {
    prefix_cache_from(crate::util::env::var("AO_PREFIX_CACHE").as_deref())
}

/// Parse an optional AO_MAX_BATCH_TOKENS value (None/"" -> scheduler
/// off, i.e. the legacy burst-FCFS admit/decode barrier). Any other
/// value must be a positive integer token budget.
pub fn max_batch_tokens_from(var: Option<&str>) -> Result<Option<usize>> {
    match var {
        None | Some("") => Ok(None),
        Some(v) => {
            let n: usize = v.parse().map_err(|_| {
                anyhow::anyhow!(
                    "AO_MAX_BATCH_TOKENS: '{v}' is not a positive integer \
                     token budget (unset or empty disables the scheduler)"
                )
            })?;
            if n == 0 {
                anyhow::bail!(
                    "AO_MAX_BATCH_TOKENS: 0 is not a valid budget (unset \
                     or empty disables the scheduler)"
                );
            }
            Ok(Some(n))
        }
    }
}

/// Iteration-level scheduler budget benches serve with:
/// AO_MAX_BATCH_TOKENS (off default).
pub fn bench_max_batch_tokens() -> Result<Option<usize>> {
    max_batch_tokens_from(
        crate::util::env::var("AO_MAX_BATCH_TOKENS").as_deref(),
    )
}

/// Parse an optional AO_EOS_TOKEN value (None/"" -> decode the full
/// `max_new_tokens` budget, no early stop).
pub fn eos_token_from(var: Option<&str>) -> Result<Option<u32>> {
    match var {
        None | Some("") => Ok(None),
        Some(v) => v.parse::<u32>().map(Some).map_err(|_| {
            anyhow::anyhow!(
                "AO_EOS_TOKEN: '{v}' is not a token id (unset or empty \
                 disables early stop)"
            )
        }),
    }
}

/// EOS early-stop token benches serve with: AO_EOS_TOKEN (off default).
pub fn bench_eos_token() -> Result<Option<u32>> {
    eos_token_from(crate::util::env::var("AO_EOS_TOKEN").as_deref())
}

/// Parse an optional AO_FAULT_RETRIES value (None/"" -> the engine
/// default of 3 transient-failure retries).
pub fn fault_retries_from(var: Option<&str>) -> Result<usize> {
    match var {
        None | Some("") => Ok(3),
        Some(v) => v.parse::<usize>().map_err(|_| {
            anyhow::anyhow!(
                "AO_FAULT_RETRIES: '{v}' is not a retry count (unset or \
                 empty keeps the default of 3)"
            )
        }),
    }
}

/// Transient-failure retry budget benches serve with: AO_FAULT_RETRIES.
pub fn bench_fault_retries() -> Result<usize> {
    fault_retries_from(crate::util::env::var("AO_FAULT_RETRIES").as_deref())
}

/// Parse an optional AO_FAULT_BACKOFF_MS value (None/"" -> the engine
/// default of a 10ms base backoff, doubling per retry).
pub fn fault_backoff_ms_from(var: Option<&str>) -> Result<u64> {
    match var {
        None | Some("") => Ok(10),
        Some(v) => v.parse::<u64>().map_err(|_| {
            anyhow::anyhow!(
                "AO_FAULT_BACKOFF_MS: '{v}' is not a duration in \
                 milliseconds (unset or empty keeps the default of 10)"
            )
        }),
    }
}

/// Base retry backoff benches serve with: AO_FAULT_BACKOFF_MS.
pub fn bench_fault_backoff_ms() -> Result<u64> {
    fault_backoff_ms_from(
        crate::util::env::var("AO_FAULT_BACKOFF_MS").as_deref(),
    )
}

/// Parse an optional AO_FAULT_PLAN value (None/"" -> no injector). The
/// plan itself is validated by the engine (`FaultInjector::parse`), so
/// this only normalizes the empty/unset cases.
pub fn fault_plan_from(var: Option<&str>) -> Option<String> {
    match var {
        None | Some("") => None,
        Some(v) => Some(v.to_string()),
    }
}

/// Deterministic fault plan benches serve with: AO_FAULT_PLAN (off
/// default; see docs/robustness.md for the grammar).
pub fn bench_fault_plan() -> Option<String> {
    fault_plan_from(crate::util::env::var("AO_FAULT_PLAN").as_deref())
}

/// Parse an optional AO_MAX_QUEUE value (None/"" -> unbounded queue).
pub fn max_queue_from(var: Option<&str>) -> Result<Option<usize>> {
    match var {
        None | Some("") => Ok(None),
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n > 0 => Ok(Some(n)),
            _ => anyhow::bail!(
                "AO_MAX_QUEUE: '{v}' is not a positive integer queue \
                 bound (unset or empty leaves the queue unbounded)"
            ),
        },
    }
}

/// Admission-queue bound benches serve with: AO_MAX_QUEUE (off default).
pub fn bench_max_queue() -> Result<Option<usize>> {
    max_queue_from(crate::util::env::var("AO_MAX_QUEUE").as_deref())
}

/// Parse an optional AO_DEFAULT_DEADLINE_MS value (None/"" -> no default
/// deadline).
pub fn default_deadline_ms_from(var: Option<&str>) -> Result<Option<u64>> {
    match var {
        None | Some("") => Ok(None),
        Some(v) => v.parse::<u64>().map(Some).map_err(|_| {
            anyhow::anyhow!(
                "AO_DEFAULT_DEADLINE_MS: '{v}' is not a duration in \
                 milliseconds (unset or empty disables the default \
                 deadline)"
            )
        }),
    }
}

/// Default request deadline benches serve with: AO_DEFAULT_DEADLINE_MS
/// (off default).
pub fn bench_default_deadline_ms() -> Result<Option<u64>> {
    default_deadline_ms_from(
        crate::util::env::var("AO_DEFAULT_DEADLINE_MS").as_deref(),
    )
}

/// Parse an optional AO_TRACE value (None/""/"0" -> off, "1" -> on).
pub fn trace_from(var: Option<&str>) -> Result<bool> {
    match var {
        None | Some("") | Some("0") => Ok(false),
        Some("1") => Ok(true),
        Some(other) => anyhow::bail!(
            "AO_TRACE: unknown value '{other}' (valid values: 0, 1)"
        ),
    }
}

/// Serving-trace toggle benches serve with: AO_TRACE (off default).
pub fn bench_trace() -> Result<bool> {
    trace_from(crate::util::env::var("AO_TRACE").as_deref())
}

/// Parse an optional AO_TRACE_CAPACITY value (None/"" -> 0, meaning the
/// engine default of `trace::DEFAULT_CAPACITY` events).
pub fn trace_capacity_from(var: Option<&str>) -> Result<usize> {
    match var {
        None | Some("") => Ok(0),
        Some(v) => v.parse::<usize>().map_err(|_| {
            anyhow::anyhow!(
                "AO_TRACE_CAPACITY: '{v}' is not an event count (unset \
                 or empty keeps the engine default)"
            )
        }),
    }
}

/// Trace ring capacity benches serve with: AO_TRACE_CAPACITY.
pub fn bench_trace_capacity() -> Result<usize> {
    trace_capacity_from(crate::util::env::var("AO_TRACE_CAPACITY").as_deref())
}

/// Parse an optional AO_TRACE_OUT value (None/"" -> no dump). The value
/// is a path stem: the engine writes `<stem>.jsonl` and
/// `<stem>.chrome.json` when the serve loop exits, and tracing is
/// implied even without AO_TRACE=1.
pub fn trace_out_from(var: Option<&str>) -> Option<PathBuf> {
    match var {
        None | Some("") => None,
        Some(v) => Some(PathBuf::from(v)),
    }
}

/// Trace dump stem benches serve with: AO_TRACE_OUT (off default).
pub fn bench_trace_out() -> Option<PathBuf> {
    trace_out_from(crate::util::env::var("AO_TRACE_OUT").as_deref())
}

/// Parse an optional AO_FAULT_JITTER_MS value (None/"" -> 0: no jitter,
/// chaos replays stay bit-identical).
pub fn fault_jitter_ms_from(var: Option<&str>) -> Result<u64> {
    match var {
        None | Some("") => Ok(0),
        Some(v) => v.parse::<u64>().map_err(|_| {
            anyhow::anyhow!(
                "AO_FAULT_JITTER_MS: '{v}' is not a duration in \
                 milliseconds (unset or empty disables jitter)"
            )
        }),
    }
}

/// Retry jitter cap benches serve with: AO_FAULT_JITTER_MS (off default).
pub fn bench_fault_jitter_ms() -> Result<u64> {
    fault_jitter_ms_from(
        crate::util::env::var("AO_FAULT_JITTER_MS").as_deref(),
    )
}

/// Parse an optional AO_METRICS_OUT value (None/"" -> no periodic
/// Prometheus snapshot). The value is the path the engine rewrites
/// once per SLO window and at shutdown.
pub fn metrics_out_from(var: Option<&str>) -> Option<PathBuf> {
    match var {
        None | Some("") => None,
        Some(v) => Some(PathBuf::from(v)),
    }
}

/// Prometheus snapshot path benches serve with: AO_METRICS_OUT (off
/// default).
pub fn bench_metrics_out() -> Option<PathBuf> {
    metrics_out_from(crate::util::env::var("AO_METRICS_OUT").as_deref())
}

/// Parse an optional AO_POSTMORTEM_DIR value (None/"" -> no flight
/// recorder). The value is the bundle directory the engine writes on a
/// fatal error or `{"op":"dump"}`.
pub fn postmortem_dir_from(var: Option<&str>) -> Option<PathBuf> {
    match var {
        None | Some("") => None,
        Some(v) => Some(PathBuf::from(v)),
    }
}

/// Postmortem bundle dir benches serve with: AO_POSTMORTEM_DIR (off
/// default).
pub fn bench_postmortem_dir() -> Option<PathBuf> {
    postmortem_dir_from(crate::util::env::var("AO_POSTMORTEM_DIR").as_deref())
}

/// Parse an optional AO_SLO_WINDOW_SECS value (None/"" -> 0, meaning
/// the engine default of 10-second windows).
pub fn slo_window_secs_from(var: Option<&str>) -> Result<u64> {
    match var {
        None | Some("") => Ok(0),
        Some(v) => match v.parse::<u64>() {
            Ok(n) if n > 0 => Ok(n),
            _ => anyhow::bail!(
                "AO_SLO_WINDOW_SECS: '{v}' is not a positive window \
                 width in seconds (unset or empty keeps the engine \
                 default of 10)"
            ),
        },
    }
}

/// SLO window width benches serve with: AO_SLO_WINDOW_SECS.
pub fn bench_slo_window_secs() -> Result<u64> {
    slo_window_secs_from(
        crate::util::env::var("AO_SLO_WINDOW_SECS").as_deref(),
    )
}

/// Parse an optional AO_SLO_WINDOWS value (None/"" -> 0, meaning the
/// engine default ring of `stats::SLO_WINDOWS` windows).
pub fn slo_windows_from(var: Option<&str>) -> Result<usize> {
    match var {
        None | Some("") => Ok(0),
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n > 0 => Ok(n),
            _ => anyhow::bail!(
                "AO_SLO_WINDOWS: '{v}' is not a positive window count \
                 (unset or empty keeps the engine default)"
            ),
        },
    }
}

/// SLO ring size benches serve with: AO_SLO_WINDOWS.
pub fn bench_slo_windows() -> Result<usize> {
    slo_windows_from(crate::util::env::var("AO_SLO_WINDOWS").as_deref())
}

/// Parse an optional AO_BOUNDED_STATS value (None/""/"0" -> off: exact
/// per-sample latency vectors plus histograms; "1" -> histogram-only).
pub fn bounded_stats_from(var: Option<&str>) -> Result<bool> {
    match var {
        None | Some("") | Some("0") => Ok(false),
        Some("1") => Ok(true),
        Some(other) => anyhow::bail!(
            "AO_BOUNDED_STATS: unknown value '{other}' (valid values: 0, 1)"
        ),
    }
}

/// Bounded-stats toggle benches serve with: AO_BOUNDED_STATS (off
/// default).
pub fn bench_bounded_stats() -> Result<bool> {
    bounded_stats_from(crate::util::env::var("AO_BOUNDED_STATS").as_deref())
}

/// Run a full serving workload in-process; returns engine metrics
/// (including host↔device transfer bytes — set AO_BENCH_REPORT=1 to
/// print the full engine report line per run).
pub fn serve_workload(
    model: &str,
    scheme: &str,
    ckpt_path: &Path,
    spec: &WorkloadSpec,
) -> Result<MetricsCollector> {
    serve_workload_with(model, scheme, ckpt_path, spec, bench_prefix_cache()?)
}

/// `serve_workload` with an explicit prefix-cache toggle (the table1
/// shared-system-prompt scenario A/Bs it in one process).
pub fn serve_workload_with(
    model: &str,
    scheme: &str,
    ckpt_path: &Path,
    spec: &WorkloadSpec,
    prefix_cache: bool,
) -> Result<MetricsCollector> {
    serve_workload_sched(
        model,
        scheme,
        ckpt_path,
        spec,
        prefix_cache,
        bench_max_batch_tokens()?,
    )
}

/// `serve_workload_with` with an explicit scheduler budget (the table1
/// continuous-batching scenario A/Bs scheduler on vs off in one
/// process, where the env toggle cannot vary per run).
pub fn serve_workload_sched(
    model: &str,
    scheme: &str,
    ckpt_path: &Path,
    spec: &WorkloadSpec,
    prefix_cache: bool,
    max_batch_tokens: Option<usize>,
) -> Result<MetricsCollector> {
    serve_workload_traced(
        model,
        scheme,
        ckpt_path,
        spec,
        prefix_cache,
        max_batch_tokens,
        bench_trace_out(),
    )
}

/// `serve_workload_sched` with an explicit trace dump stem (the table1
/// bench persists one traced run's timeline as a CI artifact;
/// `AO_TRACE_OUT` is the env route for every other bench run).
#[allow(clippy::too_many_arguments)]
pub fn serve_workload_traced(
    model: &str,
    scheme: &str,
    ckpt_path: &Path,
    spec: &WorkloadSpec,
    prefix_cache: bool,
    max_batch_tokens: Option<usize>,
    trace_out: Option<PathBuf>,
) -> Result<MetricsCollector> {
    let reqs = workload::generate(spec);
    let tok = Tokenizer::byte_level();
    let (handle, join) = engine::spawn(EngineConfig {
        artifacts_dir: crate::default_artifacts_dir(),
        ckpt_path: ckpt_path.to_path_buf(),
        model: model.into(),
        scheme: scheme.into(),
        // AO_KV_CACHE=int8 / AO_KV_LAYOUT=paged serve the same workload
        // on the quantized / paged cache, so every (scheme, layout)
        // combination is benchable from one binary
        cache_scheme: bench_cache_scheme()?,
        kv_layout: bench_kv_layout()?,
        // AO_EOS_TOKEN=<id> exercises EOS early-stop in any bench
        eos_token: bench_eos_token()?,
        // AO_HOST_ADMISSION=1 A/Bs the admission paths in any bench
        host_admission: crate::util::env::var("AO_HOST_ADMISSION")
            .is_some_and(|v| v == "1"),
        // AO_PREFIX_CACHE=0 A/Bs prefix sharing under the paged layout
        prefix_cache,
        // AO_MAX_BATCH_TOKENS=<budget> turns on the iteration-level
        // scheduler (continuous batching + chunked prefill)
        max_batch_tokens,
        // AO_FAULT_RETRIES / AO_FAULT_BACKOFF_MS tune transient-failure
        // containment; AO_FAULT_PLAN arms the deterministic injector so
        // chaos runs are benchable (and bit-reproducible) from any bench
        fault_retries: bench_fault_retries()?,
        fault_backoff_ms: bench_fault_backoff_ms()?,
        fault_plan: bench_fault_plan(),
        // AO_MAX_QUEUE bounds admission; AO_DEFAULT_DEADLINE_MS stamps a
        // deadline on every request that lacks one
        max_queue: bench_max_queue()?,
        default_deadline_ms: bench_default_deadline_ms()?,
        // AO_TRACE / AO_TRACE_CAPACITY / AO_TRACE_OUT record (and dump)
        // the per-step + lifecycle trace from any bench run (a dump
        // stem implies tracing, mirroring cmd_serve)
        trace: bench_trace()?,
        trace_capacity: bench_trace_capacity()?,
        trace_out,
        // AO_FAULT_JITTER_MS adds deterministic retry jitter;
        // AO_BOUNDED_STATS flips latency accounting to histogram-only
        fault_jitter_ms: bench_fault_jitter_ms()?,
        bounded_stats: bench_bounded_stats()?,
        // AO_METRICS_OUT / AO_POSTMORTEM_DIR / AO_SLO_WINDOW_SECS /
        // AO_SLO_WINDOWS wire the operational-observability surfaces
        // (Prometheus snapshot, flight recorder, rolling SLO ring) into
        // any bench run
        metrics_out: bench_metrics_out(),
        postmortem_dir: bench_postmortem_dir(),
        slo_window_secs: bench_slo_window_secs()?,
        slo_windows: bench_slo_windows()?,
    });
    let mut rxs = Vec::new();
    for r in &reqs {
        let (tx, rx) = channel();
        handle.submit(SubmitReq {
            id: r.id,
            prompt_tokens: tok.encode(&r.prompt),
            max_new_tokens: r.max_new_tokens,
            temperature: 0.0,
            seed: r.id,
            tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: None,
        })?;
        rxs.push(rx);
    }
    for rx in rxs {
        for ev in rx {
            if matches!(ev, Event::Done(_) | Event::Error(_)) {
                break;
            }
        }
    }
    handle.shutdown();
    let metrics = join.join().expect("engine thread")?;
    let report_on = crate::util::env::var("AO_BENCH_REPORT")
        .is_some_and(|v| !v.is_empty() && v != "0");
    if report_on {
        eprintln!("{}", metrics.report(&format!("{model}/{scheme}")));
    }
    Ok(metrics)
}

/// Evaluate (hellaswag-proxy acc, word ppl, token ppl) for a checkpoint.
pub fn eval_ckpt(
    model: &str,
    scheme: &str,
    ckpt_path: &Path,
    n_items: usize,
    ppl_batches: usize,
) -> Result<(f64, f64, f64)> {
    let runtime = crate::runtime::Runtime::open(&crate::default_artifacts_dir())?;
    let ckpt = Checkpoint::load(ckpt_path)?;
    let ev = crate::evalh::Evaluator::new(&runtime, model, scheme, &ckpt)?;
    let (_, val) = corpus_pair();
    let tok = Tokenizer::byte_level();
    let ids = tok.encode(&val);
    let n_words = val.split_whitespace().count();
    let ppl = ev.perplexity(&ids, n_words, ppl_batches)?;
    let items = crate::data::evaltask::generate(0xE7A1, n_items, 2);
    let acc = ev.hellaswag(&items, &tok)?;
    Ok((acc, ppl.word_ppl, ppl.token_ppl))
}

/// Fixed-width table printer for bench output.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    // stdout is this type's contract: benches pipe the table into their
    // CSV/console output, so the print_stdout lint is waived here
    #[allow(clippy::print_stdout)]
    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_selectors_default_when_unset() {
        assert_eq!(cache_scheme_from(None).unwrap(), CacheScheme::F32);
        assert_eq!(cache_scheme_from(Some("")).unwrap(), CacheScheme::F32);
        assert_eq!(
            cache_scheme_from(Some("int8")).unwrap(),
            CacheScheme::Int8
        );
        assert_eq!(kv_layout_from(None).unwrap(), KvLayout::Static);
        assert_eq!(kv_layout_from(Some("")).unwrap(), KvLayout::Static);
        assert_eq!(kv_layout_from(Some("paged")).unwrap(), KvLayout::Paged);
        assert!(prefix_cache_from(None).unwrap());
        assert!(prefix_cache_from(Some("")).unwrap());
        assert!(prefix_cache_from(Some("1")).unwrap());
        assert!(!prefix_cache_from(Some("0")).unwrap());
        let e = prefix_cache_from(Some("yes")).unwrap_err().to_string();
        assert!(e.contains("AO_PREFIX_CACHE"), "{e}");
        assert!(e.contains("valid values: 0, 1"), "{e}");
    }

    #[test]
    fn env_selector_errors_name_the_variable_and_valid_values() {
        // satellite contract: a typo'd AO_KV_CACHE / AO_KV_LAYOUT must
        // say which variable failed and what it accepts, and benches
        // propagate it through `?` so the process exits non-zero
        let e = format!("{:#}", cache_scheme_from(Some("fp4")).unwrap_err());
        assert!(e.contains("AO_KV_CACHE"), "{e}");
        assert!(e.contains("valid values: f32, int8"), "{e}");
        let e = format!("{:#}", kv_layout_from(Some("vpaged")).unwrap_err());
        assert!(e.contains("AO_KV_LAYOUT"), "{e}");
        assert!(e.contains("valid values: static, paged"), "{e}");
    }

    #[test]
    fn max_batch_tokens_env_contract() {
        assert_eq!(max_batch_tokens_from(None).unwrap(), None);
        assert_eq!(max_batch_tokens_from(Some("")).unwrap(), None);
        assert_eq!(max_batch_tokens_from(Some("24")).unwrap(), Some(24));
        let e = format!(
            "{:#}",
            max_batch_tokens_from(Some("lots")).unwrap_err()
        );
        assert!(e.contains("AO_MAX_BATCH_TOKENS"), "{e}");
        let e =
            format!("{:#}", max_batch_tokens_from(Some("0")).unwrap_err());
        assert!(e.contains("AO_MAX_BATCH_TOKENS"), "{e}");
    }

    #[test]
    fn eos_token_env_contract() {
        assert_eq!(eos_token_from(None).unwrap(), None);
        assert_eq!(eos_token_from(Some("")).unwrap(), None);
        assert_eq!(eos_token_from(Some("3")).unwrap(), Some(3));
        let e = format!("{:#}", eos_token_from(Some("eof")).unwrap_err());
        assert!(e.contains("AO_EOS_TOKEN"), "{e}");
    }

    #[test]
    fn fault_env_contract() {
        assert_eq!(fault_retries_from(None).unwrap(), 3);
        assert_eq!(fault_retries_from(Some("")).unwrap(), 3);
        assert_eq!(fault_retries_from(Some("0")).unwrap(), 0);
        assert_eq!(fault_retries_from(Some("5")).unwrap(), 5);
        let e = format!("{:#}", fault_retries_from(Some("x")).unwrap_err());
        assert!(e.contains("AO_FAULT_RETRIES"), "{e}");
        assert_eq!(fault_backoff_ms_from(None).unwrap(), 10);
        assert_eq!(fault_backoff_ms_from(Some("1")).unwrap(), 1);
        let e =
            format!("{:#}", fault_backoff_ms_from(Some("x")).unwrap_err());
        assert!(e.contains("AO_FAULT_BACKOFF_MS"), "{e}");
        assert_eq!(fault_plan_from(None), None);
        assert_eq!(fault_plan_from(Some("")), None);
        assert_eq!(
            fault_plan_from(Some("exec:decode:at=3")).as_deref(),
            Some("exec:decode:at=3")
        );
    }

    #[test]
    fn admission_env_contract() {
        assert_eq!(max_queue_from(None).unwrap(), None);
        assert_eq!(max_queue_from(Some("")).unwrap(), None);
        assert_eq!(max_queue_from(Some("8")).unwrap(), Some(8));
        let e = format!("{:#}", max_queue_from(Some("0")).unwrap_err());
        assert!(e.contains("AO_MAX_QUEUE"), "{e}");
        assert_eq!(default_deadline_ms_from(None).unwrap(), None);
        assert_eq!(default_deadline_ms_from(Some("")).unwrap(), None);
        assert_eq!(
            default_deadline_ms_from(Some("250")).unwrap(),
            Some(250)
        );
        let e = format!(
            "{:#}",
            default_deadline_ms_from(Some("soon")).unwrap_err()
        );
        assert!(e.contains("AO_DEFAULT_DEADLINE_MS"), "{e}");
    }

    #[test]
    fn trace_env_contract() {
        assert!(!trace_from(None).unwrap());
        assert!(!trace_from(Some("")).unwrap());
        assert!(!trace_from(Some("0")).unwrap());
        assert!(trace_from(Some("1")).unwrap());
        let e = format!("{:#}", trace_from(Some("yes")).unwrap_err());
        assert!(e.contains("AO_TRACE"), "{e}");
        assert_eq!(trace_capacity_from(None).unwrap(), 0);
        assert_eq!(trace_capacity_from(Some("")).unwrap(), 0);
        assert_eq!(trace_capacity_from(Some("512")).unwrap(), 512);
        let e =
            format!("{:#}", trace_capacity_from(Some("big")).unwrap_err());
        assert!(e.contains("AO_TRACE_CAPACITY"), "{e}");
        assert_eq!(trace_out_from(None), None);
        assert_eq!(trace_out_from(Some("")), None);
        assert_eq!(
            trace_out_from(Some("runs/trace")),
            Some(PathBuf::from("runs/trace"))
        );
    }

    #[test]
    fn observability_env_contract() {
        assert_eq!(metrics_out_from(None), None);
        assert_eq!(metrics_out_from(Some("")), None);
        assert_eq!(
            metrics_out_from(Some("runs/metrics.prom")),
            Some(PathBuf::from("runs/metrics.prom"))
        );
        assert_eq!(postmortem_dir_from(None), None);
        assert_eq!(postmortem_dir_from(Some("")), None);
        assert_eq!(
            postmortem_dir_from(Some("runs/postmortem")),
            Some(PathBuf::from("runs/postmortem"))
        );
        assert_eq!(slo_window_secs_from(None).unwrap(), 0);
        assert_eq!(slo_window_secs_from(Some("")).unwrap(), 0);
        assert_eq!(slo_window_secs_from(Some("5")).unwrap(), 5);
        let e =
            format!("{:#}", slo_window_secs_from(Some("0")).unwrap_err());
        assert!(e.contains("AO_SLO_WINDOW_SECS"), "{e}");
        let e =
            format!("{:#}", slo_window_secs_from(Some("x")).unwrap_err());
        assert!(e.contains("AO_SLO_WINDOW_SECS"), "{e}");
        assert_eq!(slo_windows_from(None).unwrap(), 0);
        assert_eq!(slo_windows_from(Some("")).unwrap(), 0);
        assert_eq!(slo_windows_from(Some("16")).unwrap(), 16);
        let e = format!("{:#}", slo_windows_from(Some("0")).unwrap_err());
        assert!(e.contains("AO_SLO_WINDOWS"), "{e}");
        let e =
            format!("{:#}", slo_windows_from(Some("many")).unwrap_err());
        assert!(e.contains("AO_SLO_WINDOWS"), "{e}");
    }

    #[test]
    fn jitter_and_bounded_stats_env_contract() {
        assert_eq!(fault_jitter_ms_from(None).unwrap(), 0);
        assert_eq!(fault_jitter_ms_from(Some("")).unwrap(), 0);
        assert_eq!(fault_jitter_ms_from(Some("7")).unwrap(), 7);
        let e =
            format!("{:#}", fault_jitter_ms_from(Some("x")).unwrap_err());
        assert!(e.contains("AO_FAULT_JITTER_MS"), "{e}");
        assert!(!bounded_stats_from(None).unwrap());
        assert!(!bounded_stats_from(Some("")).unwrap());
        assert!(!bounded_stats_from(Some("0")).unwrap());
        assert!(bounded_stats_from(Some("1")).unwrap());
        let e =
            format!("{:#}", bounded_stats_from(Some("on")).unwrap_err());
        assert!(e.contains("AO_BOUNDED_STATS"), "{e}");
    }
}
