//! `ao` — the launcher. Subcommands cover the paper's whole workflow:
//!
//!   ao gen-data   --model small                 # corpus + tokenizer
//!   ao train      --model small --recipe bf16 --steps 100
//!   ao quantize   --ckpt runs/small.aockpt --scheme int4wo-64
//!   ao eval       --ckpt runs/small_int4wo-64.aockpt --scheme int4wo-64
//!   ao serve      --ckpt ... --scheme fp8dq_row --addr 127.0.0.1:7433
//!                 [--artifacts DIR]   # manifest dir (default: artifacts/)
//!                 [--kv-cache int8]   # quantized (int8+scales) KV cache
//!                 [--kv-layout paged] # block-table paged KV cache
//!                 [--no-prefix-cache] # disable shared-prefix page reuse
//!                 [--max-batch-tokens 256] # iteration-level scheduler:
//!                                     # per-step token budget mixing
//!                                     # decode rows + prefill chunks
//!                 [--host-admission]  # force the host splice fallback
//!                 [--eos-token ID]    # stop decoding at this token id
//!                 [--fault-retries 3] # transient-failure retry budget
//!                 [--fault-backoff-ms 10] # base retry backoff (doubles)
//!                 [--fault-plan SPEC] # deterministic fault injection,
//!                                     # e.g. exec:decode:every=7:n=3
//!                 [--fault-jitter-ms MS] # deterministic retry jitter cap
//!                 [--max-queue N]     # bounded admission queue; full ->
//!                                     # reject with kind "overloaded"
//!                 [--default-deadline-ms MS] # deadline for requests
//!                                     # that don't carry their own
//!                 [--trace]           # per-step + lifecycle event ring
//!                 [--trace-capacity N] # trace ring bound (default 4096)
//!                 [--trace-out STEM]  # dump STEM.jsonl + STEM.chrome.json
//!                 [--bounded-stats]   # histogram-only latency accounting
//!                 [--metrics-out PATH] # periodic Prometheus snapshot file
//!                 [--postmortem-dir DIR] # flight-recorder bundle on fatal
//!                                     # error or {"op":"dump"}
//!                 [--slo-window-secs S] # rolling-SLO window width (10)
//!                 [--slo-windows N]   # rolling-SLO ring length (32)
//!   ao bench-client --addr 127.0.0.1:7433 --n 16
//!   ao perfmodel  [--kernels]                   # H100/Fig3 + L1 estimates

use anyhow::{bail, Context, Result};
use ao::coordinator::{engine, server};
use ao::data::{corpus, dataset::PackedDataset, evaltask, workload};
use ao::evalh::Evaluator;
use ao::quant::QuantConfig;
use ao::runtime::Runtime;
use ao::tokenizer::Tokenizer;
use ao::train::Trainer;
use ao::util::cli::Args;
use std::path::PathBuf;
use std::sync::Arc;

fn main() {
    ao::util::log::init();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "gen-data" => cmd_gen_data(&args),
        "train" => cmd_train(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "bench-client" => cmd_bench_client(&args),
        "perfmodel" => cmd_perfmodel(&args),
        "artifacts" => cmd_artifacts(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "ao — TorchAO-style training-to-serving model optimization\n\
         commands: gen-data, train, quantize, eval, serve, bench-client,\n\
         \x20          perfmodel, artifacts"
    );
}

fn runs_path(name: &str) -> PathBuf {
    ao::runs_dir().join(name)
}

/// gen-data: synth corpus + tokenizer, saved under runs/.
fn cmd_gen_data(args: &Args) -> Result<()> {
    let train_kb = args.usize_or("train-kb", 512);
    let val_kb = args.usize_or("val-kb", 64);
    let seed = args.usize_or("seed", 7) as u64;
    let c = corpus::standard_corpus(seed, train_kb * 1024, val_kb * 1024);
    std::fs::write(runs_path("corpus_train.txt"), &c.train)?;
    std::fs::write(runs_path("corpus_val.txt"), &c.val)?;
    let tok = Tokenizer::byte_level();
    tok.save(&runs_path("tokenizer.json"))?;
    println!(
        "wrote runs/corpus_train.txt ({} KiB), runs/corpus_val.txt ({} KiB), \
         runs/tokenizer.json (vocab {})",
        c.train.len() / 1024,
        c.val.len() / 1024,
        tok.vocab_size
    );
    Ok(())
}

fn load_corpus() -> Result<(String, String)> {
    let train = std::fs::read_to_string(runs_path("corpus_train.txt"))
        .context("run `ao gen-data` first")?;
    let val = std::fs::read_to_string(runs_path("corpus_val.txt"))?;
    Ok((train, val))
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.str_or("model", "small");
    let recipe = args.str_or("recipe", "bf16");
    let steps = args.usize_or("steps", 100);
    let seed = args.usize_or("seed", 0) as i32;
    let out = args.str_or("out", &format!("{model}_{recipe}.aockpt"));
    let artifacts = ao::default_artifacts_dir();
    let (train_text, _) = load_corpus()?;
    let tok = Tokenizer::byte_level();

    let mut trainer = Trainer::new(&artifacts, &model, &recipe, seed)?;
    let ds = PackedDataset::from_text(&tok, &train_text, trainer.seq());
    println!(
        "training model={model} recipe={recipe} steps={steps} \
         batch={} seq={}",
        trainer.batch(),
        trainer.seq()
    );
    let mut loss_log = String::from("step,loss,seconds\n");
    let report = trainer.run(&ds, steps, 0xA0, |i, loss, dt| {
        loss_log.push_str(&format!("{i},{loss},{dt:.4}\n"));
        if i % 10 == 0 || i + 1 == steps {
            println!("  step {i:>4}  loss {loss:.4}  ({dt:.2}s)");
        }
    })?;
    std::fs::write(
        runs_path(&format!("loss_{model}_{recipe}.csv")),
        &loss_log,
    )?;
    let ckpt = trainer.export_checkpoint()?;
    let ckpt_path = runs_path(&out);
    ckpt.save(&ckpt_path)?;
    println!(
        "final loss {:.4}; median {:.1} tok/s; peak RSS {} MiB\n\
         checkpoint -> {}",
        report.final_loss(),
        report.median_tok_per_s(),
        report.peak_rss_bytes / (1024 * 1024),
        ckpt_path.display()
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let ckpt_path = PathBuf::from(
        args.get("ckpt").context("--ckpt <master.aockpt> required")?,
    );
    let scheme = args.str_or("scheme", "int4wo-64");
    let cfg = QuantConfig::parse(&scheme)?;
    let master = ao::ckpt::Checkpoint::load(&ckpt_path)?;
    let (packed, report) = ao::quant::quantize_checkpoint(&master, cfg)?;
    let stem = ckpt_path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("model");
    let out = args.str_or("out", &format!("{stem}_{scheme}.aockpt"));
    let out_path = ckpt_path.with_file_name(&out);
    packed.save(&out_path)?;
    println!(
        "quantized {} -> {}\n  scheme {scheme}: {:.2} MiB -> {:.2} MiB \
         ({:.2}x smaller)",
        ckpt_path.display(),
        out_path.display(),
        report.f32_bytes as f64 / (1024.0 * 1024.0),
        report.packed_bytes as f64 / (1024.0 * 1024.0),
        report.ratio()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let ckpt_path = PathBuf::from(
        args.get("ckpt").context("--ckpt <ckpt.aockpt> required")?,
    );
    let model = args.str_or("model", "small");
    let scheme = args.str_or("scheme", "f32");
    let n_items = args.usize_or("hellaswag-items", 64);
    let max_batches = args.usize_or("ppl-batches", 8);
    let artifacts = ao::default_artifacts_dir();
    let (_, val_text) = load_corpus()?;
    let tok = Tokenizer::byte_level();
    let runtime = Runtime::open(&artifacts)?;
    let ckpt = ao::ckpt::Checkpoint::load(&ckpt_path)?;
    let ev = Evaluator::new(&runtime, &model, &scheme, &ckpt)?;
    let ids = tok.encode(&val_text);
    let n_words = val_text.split_whitespace().count();
    let ppl = ev.perplexity(&ids, n_words, max_batches)?;
    let items = evaltask::generate(0xE7A1, n_items, 2);
    let acc = ev.hellaswag(&items, &tok)?;
    println!(
        "eval model={model} scheme={scheme}\n  token ppl {:.3}  word ppl \
         {:.3}  ({} tokens)\n  hellaswag-proxy acc {:.1}% ({} items)",
        ppl.token_ppl,
        ppl.word_ppl,
        ppl.n_tokens,
        acc * 100.0,
        n_items
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let ckpt_path = PathBuf::from(
        args.get("ckpt").context("--ckpt <packed.aockpt> required")?,
    );
    let model = args.str_or("model", "small");
    let scheme = args.str_or("scheme", "f32");
    let addr = args.str_or("addr", "127.0.0.1:7433");
    let max_conns = args
        .get("max-conns")
        .map(|v| {
            v.parse()
                .with_context(|| format!("--max-conns '{v}' is not a number"))
        })
        .transpose()?;
    let cfg = engine::EngineConfig {
        artifacts_dir: args
            .get("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(ao::default_artifacts_dir),
        ckpt_path,
        model,
        scheme,
        cache_scheme: engine::CacheScheme::parse(
            &args.str_or("kv-cache", "f32"),
        )
        .context("--kv-cache")?,
        kv_layout: engine::KvLayout::parse(
            &args.str_or("kv-layout", "static"),
        )
        .context("--kv-layout")?,
        eos_token: args
            .get("eos-token")
            .map(|v| {
                v.parse::<u32>().with_context(|| {
                    format!("--eos-token '{v}' is not a token id")
                })
            })
            .transpose()?,
        host_admission: args.flag("host-admission"),
        // prefix sharing defaults on; it is a no-op under the static
        // layout or without admit_suffix artifacts
        prefix_cache: !args.flag("no-prefix-cache"),
        // --max-batch-tokens <budget> turns on the iteration-level
        // scheduler (continuous batching + chunked prefill); absent =
        // the legacy burst-FCFS admit/decode barrier
        max_batch_tokens: args
            .get("max-batch-tokens")
            .map(|v| {
                v.parse::<usize>().ok().filter(|&n| n > 0).with_context(
                    || {
                        format!(
                            "--max-batch-tokens '{v}' is not a positive \
                             integer token budget"
                        )
                    },
                )
            })
            .transpose()?,
        // fault containment: transient execution/transfer failures are
        // retried with exponential backoff before the step is failed
        fault_retries: args.usize_or("fault-retries", 3),
        fault_backoff_ms: args.usize_or("fault-backoff-ms", 10) as u64,
        // --fault-plan <spec> arms the deterministic injector (chaos
        // testing); see docs/robustness.md for the grammar
        fault_plan: args.get("fault-plan").map(|s| s.to_string()),
        // --max-queue <n> bounds the admission queue; a full queue
        // rejects with a typed `overloaded` error instead of queueing
        // without limit
        max_queue: args
            .get("max-queue")
            .map(|v| {
                v.parse::<usize>().ok().filter(|&n| n > 0).with_context(
                    || {
                        format!(
                            "--max-queue '{v}' is not a positive integer \
                             queue bound"
                        )
                    },
                )
            })
            .transpose()?,
        // --default-deadline-ms <ms> stamps a completion deadline on
        // requests that don't carry their own "deadline_ms"
        default_deadline_ms: args
            .get("default-deadline-ms")
            .map(|v| {
                v.parse::<u64>().with_context(|| {
                    format!(
                        "--default-deadline-ms '{v}' is not a duration in \
                         milliseconds"
                    )
                })
            })
            .transpose()?,
        // --trace records per-step + per-request lifecycle events into
        // a bounded ring; --trace-out <stem> dumps them at exit (and
        // implies --trace)
        trace: args.flag("trace"),
        // --trace-capacity <n> bounds the ring (0 = default 4096)
        trace_capacity: args.usize_or("trace-capacity", 0),
        trace_out: args.get("trace-out").map(PathBuf::from),
        // --fault-jitter-ms <ms> caps the deterministic per-retry jitter
        // added to the transient-fault backoff (0 = off)
        fault_jitter_ms: args.usize_or("fault-jitter-ms", 0) as u64,
        // --bounded-stats keeps latency accounting in streaming
        // histograms only (no per-sample vectors)
        bounded_stats: args.flag("bounded-stats"),
        // --metrics-out <path> rewrites a Prometheus text snapshot at
        // least once per SLO window while serving, and at shutdown
        metrics_out: args.get("metrics-out").map(PathBuf::from),
        // --postmortem-dir <dir> arms the flight recorder: a fatal
        // engine error or {"op":"dump"} writes the bundle there
        postmortem_dir: args.get("postmortem-dir").map(PathBuf::from),
        // --slo-window-secs / --slo-windows shape the rolling-SLO ring
        // (0 = defaults: 32 windows of 10s, a 320s horizon)
        slo_window_secs: args.usize_or("slo-window-secs", 0) as u64,
        slo_windows: args.usize_or("slo-windows", 0),
    };
    let (handle, join) = engine::spawn(cfg);
    let tok = Arc::new(Tokenizer::byte_level());
    server::serve(&addr, handle.clone(), tok, max_conns)?;
    handle.shutdown();
    match join.join() {
        Ok(Ok(metrics)) => println!("{}", metrics.report("serve")),
        Ok(Err(e)) => bail!("engine failed: {e:#}"),
        Err(_) => bail!("engine thread panicked"),
    }
    Ok(())
}

fn cmd_bench_client(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7433");
    let n = args.usize_or("n", 16);
    let max_new = args.usize_or("max-new", 32);
    let spec = workload::WorkloadSpec {
        n_requests: n,
        max_output_tokens: max_new,
        ..Default::default()
    };
    let reqs = workload::generate(&spec);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for r in reqs {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Result<(usize, f64, f64)> {
            let mut client = server::Client::connect(&addr)?;
            let g = client.generate(&r.prompt, r.max_new_tokens, 0.0)?;
            Ok((g.n_generated, g.ttft_ms, g.tpot_ms))
        }));
    }
    let mut total_tokens = 0usize;
    let mut ttfts = Vec::new();
    let mut tpots = Vec::new();
    for h in handles {
        let (n_gen, ttft, tpot) = h.join().unwrap()?;
        total_tokens += n_gen;
        ttfts.push(ttft);
        if tpot > 0.0 {
            tpots.push(tpot);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let s_ttft = ao::util::stats::summarize(&ttfts);
    let s_tpot = ao::util::stats::summarize(&tpots);
    println!(
        "bench-client: {n} requests, {total_tokens} output tokens in \
         {wall:.2}s\n  throughput {:.1} tok/s  TTFT p50 {:.0}ms  TPOT p50 \
         {:.2}ms",
        total_tokens as f64 / wall,
        s_ttft.p50,
        s_tpot.p50
    );
    Ok(())
}

fn cmd_perfmodel(args: &Args) -> Result<()> {
    use ao::perfmodel::{fig3_speedup, kernel_report, table3_speedup, H100};
    if args.flag("kernels") {
        println!("L1 kernel estimates (TPU-v4-like core, VMEM 16 MiB):");
        println!(
            "{:<22} {:>6} {:>6} {:>6} {:>10} {:>10} {:>8}",
            "kernel", "bm", "bn", "K", "VMEM KiB", "flop/B", "MXU"
        );
        for k in kernel_report() {
            println!(
                "{:<22} {:>6} {:>6} {:>6} {:>10} {:>10.1} {:>7.0}%",
                k.name, k.block_m, k.block_n, k.k,
                k.vmem_bytes / 1024, k.intensity, k.mxu_util * 100.0
            );
        }
        return Ok(());
    }
    println!("model: H100 FP8-vs-BF16 speedup (Fig 3 grid):");
    let sizes = [1024usize, 2048, 4096, 8192, 16384];
    print!("{:>8} {:>8} |", "M", "K");
    for n in sizes {
        print!(" {n:>7}");
    }
    println!();
    for m in sizes {
        for k in sizes {
            print!("{m:>8} {k:>8} |");
            for n in sizes {
                print!(" {:>7.2}", fig3_speedup(&H100, m, k, n));
            }
            println!();
        }
    }
    println!("\nmodel: Table 3 training-step speedups (Llama3-8B dims):");
    for r in ["fp8_tensorwise", "fp8_rowwise", "fp8_rowwise_gw_hp"] {
        println!("  {r:<20} {:.2}x", table3_speedup(&H100, r));
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let runtime = Runtime::open(&ao::default_artifacts_dir())?;
    let filter = args.get("kind");
    println!("{} artifacts:", runtime.manifest.artifacts.len());
    for a in runtime.manifest.artifacts.values() {
        if filter.map_or(true, |k| a.kind == k) {
            println!(
                "  {:<44} kind={:<8} inputs={} outputs={}",
                a.name, a.kind, a.inputs.len(), a.outputs.len()
            );
        }
    }
    Ok(())
}
