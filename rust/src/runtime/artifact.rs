//! manifest.json parsing: the aot.py <-> Rust contract.

// ao-lint: allow-file(index) -- shape/geometry access sits directly after
// the length checks that establish its bounds (validate_admission checks
// `inputs.len()` before positional access; kshape is checked to be rank
// 5). Panic discipline (allow(panic)) is still enforced site-by-site.

use crate::util::json::Value;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    /// Logical payload size in bytes; None when the dtype is not one the
    /// host tensor layer knows (transfer metering then skips it).
    pub fn byte_size(&self) -> Option<usize> {
        let dt = crate::tensor::DType::parse(&self.dtype).ok()?;
        Some(self.shape.iter().product::<usize>() * dt.size())
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub model: Option<String>,
    pub scheme: Option<String>,
    pub recipe: Option<String>,
    pub batch: usize,
    pub seq: usize,
    pub smax: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// `(output_tuple_index, parameter_number)` pairs the runtime may
    /// compile as XLA input-output aliases (buffer donation) — the
    /// exporter declares them for the KV cache arguments of decode/admit.
    pub donate: Vec<(usize, usize)>,
    /// KV-cache storage scheme of decode/admit artifacts ("f32" or
    /// "int8"); manifests predating the field mean f32.
    pub cache: String,
    /// KV-cache layout of decode/admit artifacts ("static" or "paged");
    /// manifests predating the field mean static.
    pub layout: String,
    /// Positions per page ("paged" layout only; 0 otherwise).
    pub page_size: usize,
    /// Page-pool size ("paged" layout only; 0 otherwise).
    pub n_pages: usize,
}

impl ArtifactSpec {
    /// Indices of inputs whose name starts with `prefix.`.
    pub fn input_indices(&self, prefix: &str) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.name == prefix || s.name.starts_with(&format!("{prefix}."))
            })
            .map(|(i, _)| i)
            .collect()
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| {
                anyhow!("artifact '{}' has no input '{name}'", self.name)
            })
    }

    pub fn output_index(&self, suffix: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name.ends_with(suffix))
    }

    /// Names of the cache inputs this artifact binds, in binding order:
    /// `(kcache, vcache)` for the f32 scheme, `(kcache, kscale, vcache,
    /// vscale)` for int8. Errors on an unknown cache tag.
    pub fn cache_input_names(&self) -> Result<&'static [&'static str]> {
        match self.cache.as_str() {
            "f32" => Ok(&["kcache", "vcache"]),
            "int8" => Ok(&["kcache", "kscale", "vcache", "vscale"]),
            other => anyhow::bail!(
                "artifact '{}' declares unsupported KV-cache scheme \
                 '{other}' (expected f32 or int8)",
                self.name
            ),
        }
    }

    /// The trailing non-param inputs this decode/admit artifact binds
    /// after the cache block, dictated by its layout: the static layout
    /// addresses cache rows directly, the paged layout addresses pages
    /// through a per-slot block table.
    pub fn layout_trailing_inputs(&self) -> Result<&'static [&'static str]> {
        match (self.kind.as_str(), self.layout.as_str()) {
            ("admit", "static") => Ok(&["tokens", "lens", "slot_ids"]),
            ("admit", "paged") => Ok(&["tokens", "lens", "block_tables"]),
            ("admit_suffix", "paged") => {
                Ok(&["tokens", "lens", "start_lens", "block_tables"])
            }
            ("admit_suffix", "static") => anyhow::bail!(
                "artifact '{}': admit_suffix is paged-only (the static \
                 layout has no pages to share)",
                self.name
            ),
            ("decode", "static") => Ok(&["token", "pos"]),
            ("decode", "paged") => Ok(&["token", "pos", "block_tables"]),
            (_, other) => anyhow::bail!(
                "artifact '{}' declares unsupported KV layout '{other}' \
                 (valid values: static, paged)",
                self.name
            ),
        }
    }

    /// Validate the paged-layout geometry fields against the kcache
    /// spec: `page_size`/`n_pages` present and consistent with the page
    /// tensor `[L, n_pages, Hkv, page_size, Dh]`, and `page_size`
    /// dividing `smax` (the block table's logical extent). Shared by
    /// `validate_admit` and the engine's decode-artifact startup check.
    pub fn check_paged_geometry(&self, kshape: &[usize]) -> Result<()> {
        let ctx = |what: &str| {
            format!("paged artifact '{}': {what}", self.name)
        };
        if self.page_size == 0 || self.n_pages == 0 {
            anyhow::bail!(ctx(
                "manifest must declare page_size and n_pages"
            ));
        }
        if self.smax == 0 || self.smax % self.page_size != 0 {
            anyhow::bail!(
                "{} (smax={}, page_size={})",
                ctx("page_size must divide smax"),
                self.smax,
                self.page_size
            );
        }
        if kshape.len() != 5
            || kshape[1] != self.n_pages
            || kshape[3] != self.page_size
        {
            anyhow::bail!(
                "{} (got {kshape:?}, n_pages={}, page_size={})",
                ctx("kcache must be [L, n_pages, Hkv, page_size, Dh]"),
                self.n_pages,
                self.page_size
            );
        }
        // mirror of aot.py's --kv-pages floor: a pool below one
        // full-context reservation could never admit a window-spanning
        // request, so the engine would reject work the exporter
        // promised to serve
        let blocks_per_slot = self.smax / self.page_size;
        if self.n_pages < blocks_per_slot {
            anyhow::bail!(
                "{} (n_pages={} < smax/page_size={blocks_per_slot}; a \
                 full-context request could never be admitted — \
                 re-export with --kv-pages >= {blocks_per_slot})",
                ctx("page pool is below one full-context reservation"),
                self.n_pages
            );
        }
        Ok(())
    }

    /// Validate the `admit` artifact contract the serving engine binds to:
    /// trailing inputs `(cache block…, tokens, lens, slot_ids)` after the
    /// params block (`block_tables` instead of `slot_ids` under the paged
    /// layout), outputs `(logits, cache block…')`, and cache shapes
    /// consistent with `batch`/`seq`/`smax` (static) or
    /// `n_pages`/`page_size` (paged). The cache block is dictated by the
    /// artifact's `cache` scheme: `(kcache, vcache)` f32 tensors, or
    /// `(kcache, kscale, vcache, vscale)` with int8 values and f32
    /// per-(layer, slot, head, position) scales. A manifest entry that
    /// fails this check would make the engine scatter rows into the wrong
    /// place, so callers should treat an error as fatal.
    pub fn validate_admit(&self) -> Result<()> {
        self.validate_admission("admit")
    }

    /// `validate_admit` for the prefix-cache suffix-prefill artifact:
    /// same cache block and outputs, but the trailing inputs are
    /// `(tokens, lens, start_lens, block_tables)` with a FULL-WINDOW
    /// block table (`smax/page_size` blocks — the graph attends through
    /// the shared prefix pages, not just the bucket's own blocks).
    pub fn validate_admit_suffix(&self) -> Result<()> {
        self.validate_admission("admit_suffix")
    }

    fn validate_admission(&self, want_kind: &str) -> Result<()> {
        if self.kind != want_kind {
            anyhow::bail!(
                "artifact '{}' is not kind={want_kind}",
                self.name
            );
        }
        let ctx = |what: &str| {
            format!("{want_kind} artifact '{}': {what}", self.name)
        };
        let cache_names = self.cache_input_names()?;
        let quantized = self.cache == "int8";
        let paged = self.layout == "paged";
        // The engine binds buffers POSITIONALLY (params..., cache block,
        // tokens, lens, slot_ids|block_tables), so the trailing inputs
        // must sit at exactly those positions — lens/slot_ids share a
        // shape and kcache/vcache are identical, so a name-only check
        // would let a reordered manifest scatter rows into garbage slots.
        let mut trailing: Vec<&str> = cache_names.to_vec();
        trailing.extend(self.layout_trailing_inputs()?);
        if self.inputs.len() < trailing.len() {
            anyhow::bail!(ctx(&format!(
                "fewer than {} inputs",
                trailing.len()
            )));
        }
        let base = self.inputs.len() - trailing.len();
        for (off, want) in trailing.iter().enumerate() {
            let got = self.inputs[base + off].name.as_str();
            if got != *want {
                anyhow::bail!(
                    "{} (position {} is '{got}', expected '{want}')",
                    ctx(&format!(
                        "trailing inputs must be ({}) in that order",
                        trailing.join(", ")
                    )),
                    base + off
                );
            }
        }
        if let Some(bad) = self.inputs[..base]
            .iter()
            .find(|s| !s.name.starts_with("params."))
        {
            anyhow::bail!(
                "{} ('{}' is not)",
                ctx("all inputs before the cache block must be params"),
                bad.name
            );
        }
        let n_cache = cache_names.len();
        let input = |name: &str| -> Result<&IoSpec> {
            let off =
                trailing.iter().position(|n| *n == name).ok_or_else(|| {
                    anyhow!(
                        "{}",
                        ctx(&format!("no trailing input '{name}'"))
                    )
                })?;
            self.inputs.get(base + off).ok_or_else(|| {
                anyhow!("{}", ctx(&format!("missing input '{name}'")))
            })
        };
        let k = input("kcache")?;
        let kshape = &k.shape;
        if paged {
            self.check_paged_geometry(kshape)?;
        } else if kshape.len() != 5
            || kshape[1] != self.batch
            || kshape[3] != self.smax
        {
            anyhow::bail!(
                "{} (got {kshape:?}, batch={}, smax={})",
                ctx("kcache must be [L, batch, Hkv, smax, Dh]"),
                self.batch, self.smax
            );
        }
        let want_values = if quantized { "s8" } else { "f32" };
        if k.dtype != want_values {
            anyhow::bail!(
                "{} (got {})",
                ctx(&format!(
                    "{} cache values must be {want_values}",
                    self.cache
                )),
                k.dtype
            );
        }
        let v = input("vcache")?;
        if v.shape != *kshape || v.dtype != k.dtype {
            anyhow::bail!(ctx("vcache shape/dtype differs from kcache"));
        }
        if quantized {
            for name in ["kscale", "vscale"] {
                let s = input(name)?;
                if s.shape != kshape[..4] || s.dtype != "f32" {
                    anyhow::bail!(
                        "{} (got {:?} {})",
                        ctx(&format!(
                            "{name} must be f32 (values shape minus Dh)"
                        )),
                        s.shape, s.dtype
                    );
                }
            }
        }
        if input("tokens")?.shape != [self.batch, self.seq] {
            anyhow::bail!(ctx("tokens must be [batch, seq]"));
        }
        if input("lens")?.shape != [self.batch] {
            anyhow::bail!(ctx("lens must be [batch]"));
        }
        if want_kind == "admit_suffix" {
            let st = input("start_lens")?;
            if st.shape != [self.batch] || st.dtype != "s32" {
                anyhow::bail!(
                    "{} (got {:?} {})",
                    ctx("start_lens must be s32 [batch]"),
                    st.shape,
                    st.dtype
                );
            }
        }
        if paged {
            let bt = input("block_tables")?;
            // an admit's table covers only its own bucket's blocks; a
            // suffix-prefill attends through the cached prefix, so its
            // table spans the full context window
            let blocks = if want_kind == "admit_suffix" {
                self.smax / self.page_size
            } else {
                self.seq.div_ceil(self.page_size)
            };
            if bt.shape != [self.batch, blocks] {
                anyhow::bail!(
                    "{} (got {:?})",
                    ctx(&format!(
                        "block_tables must be [batch, {blocks}]"
                    )),
                    bt.shape
                );
            }
            if bt.dtype != "s32" {
                anyhow::bail!(ctx("block_tables must be s32"));
            }
        } else {
            if input("slot_ids")?.shape != [self.batch] {
                anyhow::bail!(ctx("slot_ids must be [batch]"));
            }
            if input("slot_ids")?.dtype != "s32" {
                anyhow::bail!(ctx("slot_ids must be s32"));
            }
        }
        if self.outputs.len() != 1 + n_cache {
            anyhow::bail!(ctx(&format!(
                "outputs must be (logits, {}')",
                cache_names.join("', ")
            )));
        }
        for (i, name) in cache_names.iter().enumerate() {
            let out = &self.outputs[1 + i];
            let inp = input(name)?;
            if out.shape != inp.shape || out.dtype != inp.dtype {
                anyhow::bail!(ctx(&format!(
                    "output {} ({name}') shape/dtype differs from input",
                    1 + i
                )));
            }
        }
        Ok(())
    }
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub param_count: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

/// Parse a manifest `"donate": [[out_idx, in_idx], ...]` list (absent ->
/// empty: donation is strictly opt-in per artifact).
fn donate_pairs(v: Option<&Value>) -> Result<Vec<(usize, usize)>> {
    let Some(v) = v else { return Ok(Vec::new()) };
    v.as_arr()
        .context("donate not an array")?
        .iter()
        .map(|p| {
            let pair = p.as_arr().context("donate entry not a pair")?;
            if pair.len() != 2 {
                anyhow::bail!("donate entry must be [out_idx, in_idx]");
            }
            Ok((
                pair[0].as_usize().context("donate out_idx")?,
                pair[1].as_usize().context("donate in_idx")?,
            ))
        })
        .collect()
}

fn io_specs(v: &Value) -> Result<Vec<IoSpec>> {
    v.as_arr()
        .context("io list not an array")?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e.req_str("name")?.to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()
                    .context("shape not arr")?
                    .iter()
                    .map(|d| d.as_usize().context("shape dim"))
                    .collect::<Result<Vec<usize>>>()?,
                dtype: e.req_str("dtype")?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| {
            format!(
                "read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Value::parse(text)
            .map_err(|e| anyhow!("manifest json: {e}"))?;
        let mut models = BTreeMap::new();
        for (name, m) in v.req("models")?.as_obj().context("models")? {
            models.insert(
                name.clone(),
                ModelInfo {
                    vocab: m.req_usize("vocab")?,
                    d_model: m.req_usize("d_model")?,
                    n_layers: m.req_usize("n_layers")?,
                    n_heads: m.req_usize("n_heads")?,
                    n_kv_heads: m.req_usize("n_kv_heads")?,
                    d_ff: m.req_usize("d_ff")?,
                    max_seq: m.req_usize("max_seq")?,
                    head_dim: m.req_usize("head_dim")?,
                    param_count: m.req_usize("param_count")?,
                },
            );
        }
        let mut artifacts = BTreeMap::new();
        for a in v.req("artifacts")?.as_arr().context("artifacts")? {
            let spec = ArtifactSpec {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                model: a.get("model").and_then(|x| x.as_str()).map(String::from),
                scheme: a.get("scheme").and_then(|x| x.as_str()).map(String::from),
                recipe: a.get("recipe").and_then(|x| x.as_str()).map(String::from),
                batch: a.get("batch").and_then(|x| x.as_usize()).unwrap_or(0),
                seq: a.get("seq").and_then(|x| x.as_usize()).unwrap_or(0),
                smax: a.get("smax").and_then(|x| x.as_usize()).unwrap_or(0),
                inputs: io_specs(a.req("inputs")?)?,
                outputs: io_specs(a.req("outputs")?)?,
                donate: donate_pairs(a.get("donate"))?,
                cache: a
                    .get("cache")
                    .and_then(|x| x.as_str())
                    .unwrap_or("f32")
                    .to_string(),
                layout: a
                    .get("layout")
                    .and_then(|x| x.as_str())
                    .unwrap_or("static")
                    .to_string(),
                page_size: a
                    .get("page_size")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(0),
                n_pages: a
                    .get("n_pages")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(0),
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { models, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "no artifact '{name}' in manifest (have: {})",
                self.artifacts
                    .keys()
                    .take(8)
                    .cloned()
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("no model '{name}' in manifest"))
    }

    /// Find artifacts by (kind, model, scheme/recipe).
    pub fn find(
        &self,
        kind: &str,
        model: &str,
        tag: Option<&str>,
    ) -> Vec<&ArtifactSpec> {
        self.artifacts
            .values()
            .filter(|a| {
                a.kind == kind
                    && a.model.as_deref() == Some(model)
                    && tag.map_or(true, |t| {
                        a.scheme.as_deref() == Some(t)
                            || a.recipe.as_deref() == Some(t)
                    })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {"tiny": {"vocab": 256, "d_model": 64, "n_layers": 2,
        "n_heads": 4, "n_kv_heads": 2, "d_ff": 192, "max_seq": 128,
        "head_dim": 16, "rope_theta": 10000.0, "norm_eps": 1e-5,
        "param_count": 12345}},
      "artifacts": [
        {"name": "decode_f32_tiny_b2", "file": "d.hlo.txt", "kind": "decode",
         "model": "tiny", "scheme": "f32", "batch": 2, "smax": 128,
         "inputs": [
            {"name": "params.tok_emb", "shape": [256, 64], "dtype": "f32"},
            {"name": "params.layers.wq.w", "shape": [2,64,64], "dtype": "f32"},
            {"name": "kcache", "shape": [2,2,2,128,16], "dtype": "f32"},
            {"name": "token", "shape": [2], "dtype": "s32"}],
         "outputs": [{"name": "out.0", "shape": [2,256], "dtype": "f32"}]}
      ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.models["tiny"].d_model, 64);
        let a = m.artifact("decode_f32_tiny_b2").unwrap();
        assert_eq!(a.batch, 2);
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.input_indices("params").len(), 2);
        assert_eq!(a.input_index("kcache").unwrap(), 2);
    }

    #[test]
    fn find_filters() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.find("decode", "tiny", Some("f32")).len(), 1);
        assert_eq!(m.find("decode", "tiny", Some("int8wo")).len(), 0);
        assert_eq!(m.find("prefill", "tiny", None).len(), 0);
    }

    #[test]
    fn io_byte_size() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("decode_f32_tiny_b2").unwrap();
        // params.tok_emb [256, 64] f32
        assert_eq!(a.inputs[0].byte_size(), Some(256 * 64 * 4));
        // token [2] s32
        assert_eq!(a.inputs[3].byte_size(), Some(8));
        let weird = IoSpec {
            name: "x".into(),
            shape: vec![2],
            dtype: "f64".into(),
        };
        assert_eq!(weird.byte_size(), None);
    }

    #[test]
    fn missing_artifact_error_is_helpful() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = m.artifact("nope").unwrap_err().to_string();
        assert!(err.contains("decode_f32_tiny_b2"));
    }

    const ADMIT_SAMPLE: &str = r#"{
      "version": 1,
      "models": {},
      "artifacts": [
        {"name": "admit_f32_tiny_b2_s16", "file": "a.hlo.txt",
         "kind": "admit", "model": "tiny", "scheme": "f32",
         "batch": 2, "seq": 16, "smax": 128,
         "donate": [[1, 1], [2, 2]],
         "inputs": [
            {"name": "params.tok_emb", "shape": [256, 64], "dtype": "f32"},
            {"name": "kcache", "shape": [2,2,2,128,16], "dtype": "f32"},
            {"name": "vcache", "shape": [2,2,2,128,16], "dtype": "f32"},
            {"name": "tokens", "shape": [2, 16], "dtype": "s32"},
            {"name": "lens", "shape": [2], "dtype": "s32"},
            {"name": "slot_ids", "shape": [2], "dtype": "s32"}],
         "outputs": [
            {"name": "out.0", "shape": [2, 256], "dtype": "f32"},
            {"name": "out.1", "shape": [2,2,2,128,16], "dtype": "f32"},
            {"name": "out.2", "shape": [2,2,2,128,16], "dtype": "f32"}]}
      ]}"#;

    #[test]
    fn parses_admit_and_donate() {
        let m = Manifest::parse(ADMIT_SAMPLE).unwrap();
        let a = m.artifact("admit_f32_tiny_b2_s16").unwrap();
        assert_eq!(a.kind, "admit");
        assert_eq!(a.donate, vec![(1, 1), (2, 2)]);
        a.validate_admit().unwrap();
        // artifacts without a donate field parse to an empty list
        let m2 = Manifest::parse(SAMPLE).unwrap();
        assert!(m2.artifact("decode_f32_tiny_b2").unwrap().donate.is_empty());
    }

    #[test]
    fn validate_admit_catches_contract_breaks() {
        let m = Manifest::parse(ADMIT_SAMPLE).unwrap();
        let good = m.artifact("admit_f32_tiny_b2_s16").unwrap();

        let mut missing = good.clone();
        missing.inputs.retain(|s| s.name != "slot_ids");
        assert!(missing.validate_admit().is_err(), "slot_ids required");

        let mut wrong_dtype = good.clone();
        wrong_dtype
            .inputs
            .iter_mut()
            .find(|s| s.name == "slot_ids")
            .unwrap()
            .dtype = "f32".into();
        assert!(wrong_dtype.validate_admit().is_err());

        let mut wrong_out = good.clone();
        wrong_out.outputs[1].shape = vec![2, 2, 2, 64, 16];
        assert!(wrong_out.validate_admit().is_err(), "cache shape drift");

        let mut wrong_kind = good.clone();
        wrong_kind.kind = "prefill".into();
        assert!(wrong_kind.validate_admit().is_err());

        let mut wrong_batch = good.clone();
        wrong_batch.batch = 4;
        assert!(wrong_batch.validate_admit().is_err());

        // regression (review): the engine binds positionally, and
        // lens/slot_ids share shape+dtype — a reordered manifest must NOT
        // pass just because every name exists somewhere
        let mut swapped = good.clone();
        let n = swapped.inputs.len();
        swapped.inputs.swap(n - 1, n - 2); // (..., slot_ids, lens)
        let e = swapped.validate_admit().unwrap_err().to_string();
        assert!(e.contains("in that order"), "{e}");

        let mut kv_swapped = good.clone();
        kv_swapped.inputs.swap(n - 5, n - 4); // (vcache, kcache, ...)
        assert!(kv_swapped.validate_admit().is_err());

        let mut interloper = good.clone();
        interloper.inputs[0].name = "weights.tok_emb".into();
        let e = interloper.validate_admit().unwrap_err().to_string();
        assert!(e.contains("must be params"), "{e}");
    }

    const ADMIT_KV8_SAMPLE: &str = r#"{
      "version": 1,
      "models": {},
      "artifacts": [
        {"name": "admit_f32_tiny_b2_s16_kv8", "file": "a8.hlo.txt",
         "kind": "admit", "model": "tiny", "scheme": "f32",
         "cache": "int8", "batch": 2, "seq": 16, "smax": 128,
         "donate": [[1, 1], [2, 2], [3, 3], [4, 4]],
         "inputs": [
            {"name": "params.tok_emb", "shape": [256, 64], "dtype": "f32"},
            {"name": "kcache", "shape": [2,2,2,128,16], "dtype": "s8"},
            {"name": "kscale", "shape": [2,2,2,128], "dtype": "f32"},
            {"name": "vcache", "shape": [2,2,2,128,16], "dtype": "s8"},
            {"name": "vscale", "shape": [2,2,2,128], "dtype": "f32"},
            {"name": "tokens", "shape": [2, 16], "dtype": "s32"},
            {"name": "lens", "shape": [2], "dtype": "s32"},
            {"name": "slot_ids", "shape": [2], "dtype": "s32"}],
         "outputs": [
            {"name": "out.0", "shape": [2, 256], "dtype": "f32"},
            {"name": "out.1", "shape": [2,2,2,128,16], "dtype": "s8"},
            {"name": "out.2", "shape": [2,2,2,128], "dtype": "f32"},
            {"name": "out.3", "shape": [2,2,2,128,16], "dtype": "s8"},
            {"name": "out.4", "shape": [2,2,2,128], "dtype": "f32"}]}
      ]}"#;

    #[test]
    fn parses_and_validates_int8_admit() {
        let m = Manifest::parse(ADMIT_KV8_SAMPLE).unwrap();
        let a = m.artifact("admit_f32_tiny_b2_s16_kv8").unwrap();
        assert_eq!(a.cache, "int8");
        assert_eq!(
            a.cache_input_names().unwrap(),
            &["kcache", "kscale", "vcache", "vscale"]
        );
        a.validate_admit().unwrap();
        // manifests predating the cache field mean f32
        let old = Manifest::parse(ADMIT_SAMPLE).unwrap();
        let oa = old.artifact("admit_f32_tiny_b2_s16").unwrap();
        assert_eq!(oa.cache, "f32");
        assert_eq!(oa.cache_input_names().unwrap(), &["kcache", "vcache"]);
    }

    #[test]
    fn validate_admit_int8_catches_contract_breaks() {
        let m = Manifest::parse(ADMIT_KV8_SAMPLE).unwrap();
        let good = m.artifact("admit_f32_tiny_b2_s16_kv8").unwrap();

        // int8 cache values must really be s8 (an f32 kcache would make
        // the engine upload 4x the bytes it metered)
        let mut wrong_values = good.clone();
        wrong_values.inputs[1].dtype = "f32".into();
        let e = wrong_values.validate_admit().unwrap_err().to_string();
        assert!(e.contains("must be s8"), "{e}");

        // scales carry the head axis reduced away
        let mut wrong_scale = good.clone();
        wrong_scale.inputs[2].shape = vec![2, 2, 2, 128, 16];
        let e = wrong_scale.validate_admit().unwrap_err().to_string();
        assert!(e.contains("kscale"), "{e}");

        let mut missing_scale = good.clone();
        missing_scale.inputs.remove(2);
        assert!(missing_scale.validate_admit().is_err());

        // scale outputs must round-trip like the value outputs
        let mut wrong_out = good.clone();
        wrong_out.outputs[2].shape = vec![2, 2, 2, 64];
        assert!(wrong_out.validate_admit().is_err());

        let mut unknown = good.clone();
        unknown.cache = "fp8".into();
        let e = unknown.validate_admit().unwrap_err().to_string();
        assert!(e.contains("unsupported KV-cache scheme"), "{e}");
    }

    const PAGED_SAMPLE: &str = r#"{
      "version": 1,
      "models": {},
      "artifacts": [
        {"name": "admit_f32_tiny_b2_s16_paged", "file": "ap.hlo.txt",
         "kind": "admit", "model": "tiny", "scheme": "f32",
         "layout": "paged", "page_size": 8, "n_pages": 6,
         "batch": 2, "seq": 16, "smax": 16,
         "donate": [[1, 1], [2, 2]],
         "inputs": [
            {"name": "params.tok_emb", "shape": [256, 64], "dtype": "f32"},
            {"name": "kcache", "shape": [2,6,2,8,16], "dtype": "f32"},
            {"name": "vcache", "shape": [2,6,2,8,16], "dtype": "f32"},
            {"name": "tokens", "shape": [2, 16], "dtype": "s32"},
            {"name": "lens", "shape": [2], "dtype": "s32"},
            {"name": "block_tables", "shape": [2, 2], "dtype": "s32"}],
         "outputs": [
            {"name": "out.0", "shape": [2, 256], "dtype": "f32"},
            {"name": "out.1", "shape": [2,6,2,8,16], "dtype": "f32"},
            {"name": "out.2", "shape": [2,6,2,8,16], "dtype": "f32"}]},
        {"name": "admit_f32_tiny_b2_s16_kv8_paged", "file": "ap8.hlo.txt",
         "kind": "admit", "model": "tiny", "scheme": "f32",
         "cache": "int8", "layout": "paged", "page_size": 8, "n_pages": 6,
         "batch": 2, "seq": 16, "smax": 16,
         "donate": [[1, 1], [2, 2], [3, 3], [4, 4]],
         "inputs": [
            {"name": "params.tok_emb", "shape": [256, 64], "dtype": "f32"},
            {"name": "kcache", "shape": [2,6,2,8,16], "dtype": "s8"},
            {"name": "kscale", "shape": [2,6,2,8], "dtype": "f32"},
            {"name": "vcache", "shape": [2,6,2,8,16], "dtype": "s8"},
            {"name": "vscale", "shape": [2,6,2,8], "dtype": "f32"},
            {"name": "tokens", "shape": [2, 16], "dtype": "s32"},
            {"name": "lens", "shape": [2], "dtype": "s32"},
            {"name": "block_tables", "shape": [2, 2], "dtype": "s32"}],
         "outputs": [
            {"name": "out.0", "shape": [2, 256], "dtype": "f32"},
            {"name": "out.1", "shape": [2,6,2,8,16], "dtype": "s8"},
            {"name": "out.2", "shape": [2,6,2,8], "dtype": "f32"},
            {"name": "out.3", "shape": [2,6,2,8,16], "dtype": "s8"},
            {"name": "out.4", "shape": [2,6,2,8], "dtype": "f32"}]},
        {"name": "decode_f32_tiny_b2_paged", "file": "dp.hlo.txt",
         "kind": "decode", "model": "tiny", "scheme": "f32",
         "layout": "paged", "page_size": 8, "n_pages": 6,
         "batch": 2, "smax": 16,
         "inputs": [
            {"name": "params.tok_emb", "shape": [256, 64], "dtype": "f32"},
            {"name": "kcache", "shape": [2,6,2,8,16], "dtype": "f32"},
            {"name": "vcache", "shape": [2,6,2,8,16], "dtype": "f32"},
            {"name": "token", "shape": [2], "dtype": "s32"},
            {"name": "pos", "shape": [2], "dtype": "s32"},
            {"name": "block_tables", "shape": [2, 2], "dtype": "s32"}],
         "outputs": [
            {"name": "out.0", "shape": [2, 256], "dtype": "f32"},
            {"name": "out.1", "shape": [2,6,2,8,16], "dtype": "f32"},
            {"name": "out.2", "shape": [2,6,2,8,16], "dtype": "f32"}]},
        {"name": "admit_suffix_f32_tiny_b2_s16_paged", "file": "as.hlo.txt",
         "kind": "admit_suffix", "model": "tiny", "scheme": "f32",
         "layout": "paged", "page_size": 8, "n_pages": 6,
         "batch": 2, "seq": 16, "smax": 16,
         "donate": [[1, 1], [2, 2]],
         "inputs": [
            {"name": "params.tok_emb", "shape": [256, 64], "dtype": "f32"},
            {"name": "kcache", "shape": [2,6,2,8,16], "dtype": "f32"},
            {"name": "vcache", "shape": [2,6,2,8,16], "dtype": "f32"},
            {"name": "tokens", "shape": [2, 16], "dtype": "s32"},
            {"name": "lens", "shape": [2], "dtype": "s32"},
            {"name": "start_lens", "shape": [2], "dtype": "s32"},
            {"name": "block_tables", "shape": [2, 2], "dtype": "s32"}],
         "outputs": [
            {"name": "out.0", "shape": [2, 256], "dtype": "f32"},
            {"name": "out.1", "shape": [2,6,2,8,16], "dtype": "f32"},
            {"name": "out.2", "shape": [2,6,2,8,16], "dtype": "f32"}]}
      ]}"#;

    #[test]
    fn parses_and_validates_paged_artifacts() {
        let m = Manifest::parse(PAGED_SAMPLE).unwrap();
        let a = m.artifact("admit_f32_tiny_b2_s16_paged").unwrap();
        assert_eq!(a.layout, "paged");
        assert_eq!((a.page_size, a.n_pages), (8, 6));
        assert_eq!(
            a.layout_trailing_inputs().unwrap(),
            &["tokens", "lens", "block_tables"]
        );
        a.validate_admit().unwrap();
        let a8 = m.artifact("admit_f32_tiny_b2_s16_kv8_paged").unwrap();
        assert_eq!(a8.cache, "int8");
        a8.validate_admit().unwrap();
        let d = m.artifact("decode_f32_tiny_b2_paged").unwrap();
        assert_eq!(
            d.layout_trailing_inputs().unwrap(),
            &["token", "pos", "block_tables"]
        );
        // manifests predating the layout field mean static
        let old = Manifest::parse(ADMIT_SAMPLE).unwrap();
        let oa = old.artifact("admit_f32_tiny_b2_s16").unwrap();
        assert_eq!(oa.layout, "static");
        assert_eq!((oa.page_size, oa.n_pages), (0, 0));
        assert_eq!(
            oa.layout_trailing_inputs().unwrap(),
            &["tokens", "lens", "slot_ids"]
        );
    }

    #[test]
    fn validate_admit_paged_catches_contract_breaks() {
        let m = Manifest::parse(PAGED_SAMPLE).unwrap();
        let good = m.artifact("admit_f32_tiny_b2_s16_paged").unwrap();

        // block table must cover exactly ceil(seq/page_size) blocks
        let mut bad_bt = good.clone();
        bad_bt
            .inputs
            .iter_mut()
            .find(|s| s.name == "block_tables")
            .unwrap()
            .shape = vec![2, 3];
        let e = bad_bt.validate_admit().unwrap_err().to_string();
        assert!(e.contains("block_tables must be [batch, 2]"), "{e}");

        let mut bad_dtype = bad_bt.clone();
        bad_dtype
            .inputs
            .iter_mut()
            .find(|s| s.name == "block_tables")
            .unwrap()
            .shape = vec![2, 2];
        bad_dtype
            .inputs
            .iter_mut()
            .find(|s| s.name == "block_tables")
            .unwrap()
            .dtype = "f32".into();
        assert!(bad_dtype.validate_admit().is_err());

        // page tensor must match the declared pool geometry
        let mut bad_pages = good.clone();
        bad_pages.n_pages = 7;
        let e = bad_pages.validate_admit().unwrap_err().to_string();
        assert!(e.contains("[L, n_pages, Hkv, page_size, Dh]"), "{e}");

        // missing paging geometry is fatal, not silently static
        let mut no_geom = good.clone();
        no_geom.page_size = 0;
        let e = no_geom.validate_admit().unwrap_err().to_string();
        assert!(e.contains("must declare page_size and n_pages"), "{e}");

        // page_size must tile the logical context
        let mut bad_tile = good.clone();
        bad_tile.smax = 100;
        let e = bad_tile.validate_admit().unwrap_err().to_string();
        assert!(e.contains("page_size must divide smax"), "{e}");

        // the static trailing contract must not pass for a paged entry
        let mut renamed = good.clone();
        renamed
            .inputs
            .iter_mut()
            .find(|s| s.name == "block_tables")
            .unwrap()
            .name = "slot_ids".into();
        let e = renamed.validate_admit().unwrap_err().to_string();
        assert!(e.contains("in that order"), "{e}");

        // an unknown layout names the valid values
        let mut unknown = good.clone();
        unknown.layout = "ragged".into();
        let e = unknown.validate_admit().unwrap_err().to_string();
        assert!(e.contains("valid values: static, paged"), "{e}");
    }

    #[test]
    fn paged_geometry_floors_at_one_full_context() {
        // satellite mirror of aot.py's --kv-pages validation: a pool
        // below smax/page_size could never admit a window-spanning
        // request, so the manifest is rejected up front
        let m = Manifest::parse(PAGED_SAMPLE).unwrap();
        let mut small = m.artifact("admit_f32_tiny_b2_s16_paged").unwrap().clone();
        small.smax = 64; // 8 blocks per slot > the 6-page pool
        let e = small.validate_admit().unwrap_err().to_string();
        assert!(e.contains("below one full-context reservation"), "{e}");
        assert!(e.contains("--kv-pages >= 8"), "{e}");
    }

    #[test]
    fn parses_and_validates_admit_suffix() {
        let m = Manifest::parse(PAGED_SAMPLE).unwrap();
        let s = m.artifact("admit_suffix_f32_tiny_b2_s16_paged").unwrap();
        assert_eq!(s.kind, "admit_suffix");
        assert_eq!(
            s.layout_trailing_inputs().unwrap(),
            &["tokens", "lens", "start_lens", "block_tables"]
        );
        s.validate_admit_suffix().unwrap();
        // an admit_suffix entry is NOT a valid admit (and vice versa)
        let e = s.validate_admit().unwrap_err().to_string();
        assert!(e.contains("not kind=admit"), "{e}");
        let a = m.artifact("admit_f32_tiny_b2_s16_paged").unwrap();
        assert!(a.validate_admit_suffix().is_err());
    }

    #[test]
    fn validate_admit_suffix_catches_contract_breaks() {
        let m = Manifest::parse(PAGED_SAMPLE).unwrap();
        let good = m.artifact("admit_suffix_f32_tiny_b2_s16_paged").unwrap();

        // start_lens is the position offset the suffix prefills at — a
        // wrong dtype/shape would shift every RoPE angle silently
        let mut bad_start = good.clone();
        bad_start
            .inputs
            .iter_mut()
            .find(|s| s.name == "start_lens")
            .unwrap()
            .dtype = "f32".into();
        let e = bad_start.validate_admit_suffix().unwrap_err().to_string();
        assert!(e.contains("start_lens must be s32 [batch]"), "{e}");

        // the table must span the FULL window (smax/page_size blocks),
        // not the admit bucket's ceil(seq/ps): the suffix graph attends
        // through the shared prefix pages
        let mut bad_bt = good.clone();
        bad_bt.smax = 48; // 6 blocks; table still [2, 2]
        let e = bad_bt.validate_admit_suffix().unwrap_err().to_string();
        assert!(e.contains("block_tables must be [batch, 6]"), "{e}");

        // suffix admission over the static layout is a contract break
        let mut not_paged = good.clone();
        not_paged.layout = "static".into();
        let e = not_paged.validate_admit_suffix().unwrap_err().to_string();
        assert!(e.contains("paged-only"), "{e}");

        // missing start_lens fails the positional trailing check
        let mut missing = good.clone();
        missing.inputs.retain(|s| s.name != "start_lens");
        assert!(missing.validate_admit_suffix().is_err());
    }

    #[test]
    fn donate_parse_rejects_malformed() {
        let bad = ADMIT_SAMPLE.replace("[[1, 1], [2, 2]]", "[[1], [2, 2]]");
        assert!(Manifest::parse(&bad).is_err());
        let not_arr = ADMIT_SAMPLE.replace("[[1, 1], [2, 2]]", "7");
        assert!(Manifest::parse(&not_arr).is_err());
    }
}
