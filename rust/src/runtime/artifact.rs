//! manifest.json parsing: the aot.py <-> Rust contract.

use crate::util::json::Value;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    /// Logical payload size in bytes; None when the dtype is not one the
    /// host tensor layer knows (transfer metering then skips it).
    pub fn byte_size(&self) -> Option<usize> {
        let dt = crate::tensor::DType::parse(&self.dtype).ok()?;
        Some(self.shape.iter().product::<usize>() * dt.size())
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub model: Option<String>,
    pub scheme: Option<String>,
    pub recipe: Option<String>,
    pub batch: usize,
    pub seq: usize,
    pub smax: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// Indices of inputs whose name starts with `prefix.`.
    pub fn input_indices(&self, prefix: &str) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.name == prefix || s.name.starts_with(&format!("{prefix}."))
            })
            .map(|(i, _)| i)
            .collect()
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| {
                anyhow!("artifact '{}' has no input '{name}'", self.name)
            })
    }

    pub fn output_index(&self, suffix: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name.ends_with(suffix))
    }
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub param_count: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn io_specs(v: &Value) -> Result<Vec<IoSpec>> {
    v.as_arr()
        .context("io list not an array")?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e.req_str("name")?.to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()
                    .context("shape not arr")?
                    .iter()
                    .map(|d| d.as_usize().unwrap())
                    .collect(),
                dtype: e.req_str("dtype")?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| {
            format!(
                "read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Value::parse(text)
            .map_err(|e| anyhow!("manifest json: {e}"))?;
        let mut models = BTreeMap::new();
        for (name, m) in v.req("models")?.as_obj().context("models")? {
            models.insert(
                name.clone(),
                ModelInfo {
                    vocab: m.req_usize("vocab")?,
                    d_model: m.req_usize("d_model")?,
                    n_layers: m.req_usize("n_layers")?,
                    n_heads: m.req_usize("n_heads")?,
                    n_kv_heads: m.req_usize("n_kv_heads")?,
                    d_ff: m.req_usize("d_ff")?,
                    max_seq: m.req_usize("max_seq")?,
                    head_dim: m.req_usize("head_dim")?,
                    param_count: m.req_usize("param_count")?,
                },
            );
        }
        let mut artifacts = BTreeMap::new();
        for a in v.req("artifacts")?.as_arr().context("artifacts")? {
            let spec = ArtifactSpec {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                model: a.get("model").and_then(|x| x.as_str()).map(String::from),
                scheme: a.get("scheme").and_then(|x| x.as_str()).map(String::from),
                recipe: a.get("recipe").and_then(|x| x.as_str()).map(String::from),
                batch: a.get("batch").and_then(|x| x.as_usize()).unwrap_or(0),
                seq: a.get("seq").and_then(|x| x.as_usize()).unwrap_or(0),
                smax: a.get("smax").and_then(|x| x.as_usize()).unwrap_or(0),
                inputs: io_specs(a.req("inputs")?)?,
                outputs: io_specs(a.req("outputs")?)?,
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { models, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "no artifact '{name}' in manifest (have: {})",
                self.artifacts
                    .keys()
                    .take(8)
                    .cloned()
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("no model '{name}' in manifest"))
    }

    /// Find artifacts by (kind, model, scheme/recipe).
    pub fn find(
        &self,
        kind: &str,
        model: &str,
        tag: Option<&str>,
    ) -> Vec<&ArtifactSpec> {
        self.artifacts
            .values()
            .filter(|a| {
                a.kind == kind
                    && a.model.as_deref() == Some(model)
                    && tag.map_or(true, |t| {
                        a.scheme.as_deref() == Some(t)
                            || a.recipe.as_deref() == Some(t)
                    })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {"tiny": {"vocab": 256, "d_model": 64, "n_layers": 2,
        "n_heads": 4, "n_kv_heads": 2, "d_ff": 192, "max_seq": 128,
        "head_dim": 16, "rope_theta": 10000.0, "norm_eps": 1e-5,
        "param_count": 12345}},
      "artifacts": [
        {"name": "decode_f32_tiny_b2", "file": "d.hlo.txt", "kind": "decode",
         "model": "tiny", "scheme": "f32", "batch": 2, "smax": 128,
         "inputs": [
            {"name": "params.tok_emb", "shape": [256, 64], "dtype": "f32"},
            {"name": "params.layers.wq.w", "shape": [2,64,64], "dtype": "f32"},
            {"name": "kcache", "shape": [2,2,2,128,16], "dtype": "f32"},
            {"name": "token", "shape": [2], "dtype": "s32"}],
         "outputs": [{"name": "out.0", "shape": [2,256], "dtype": "f32"}]}
      ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.models["tiny"].d_model, 64);
        let a = m.artifact("decode_f32_tiny_b2").unwrap();
        assert_eq!(a.batch, 2);
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.input_indices("params").len(), 2);
        assert_eq!(a.input_index("kcache").unwrap(), 2);
    }

    #[test]
    fn find_filters() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.find("decode", "tiny", Some("f32")).len(), 1);
        assert_eq!(m.find("decode", "tiny", Some("int8wo")).len(), 0);
        assert_eq!(m.find("prefill", "tiny", None).len(), 0);
    }

    #[test]
    fn io_byte_size() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("decode_f32_tiny_b2").unwrap();
        // params.tok_emb [256, 64] f32
        assert_eq!(a.inputs[0].byte_size(), Some(256 * 64 * 4));
        // token [2] s32
        assert_eq!(a.inputs[3].byte_size(), Some(8));
        let weird = IoSpec {
            name: "x".into(),
            shape: vec![2],
            dtype: "f64".into(),
        };
        assert_eq!(weird.byte_size(), None);
    }

    #[test]
    fn missing_artifact_error_is_helpful() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = m.artifact("nope").unwrap_err().to_string();
        assert!(err.contains("decode_f32_tiny_b2"));
    }
}
