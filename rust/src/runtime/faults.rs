//! Deterministic fault injection for chaos testing the serving loop.
//!
//! A `FaultInjector` is parsed from a fault plan (the `AO_FAULT_PLAN`
//! env binding / `--fault-plan` serve flag) and installed into the
//! `Runtime`, which consults it immediately BEFORE every execute
//! (`run_buffers`/`run_buffers_device`) and transfer (`upload`/
//! `fetch_*`) call. Firing before the real call is what makes retry
//! sound: an injected execution fault never consumed the donated cache
//! buffers, so re-running with the same inputs reproduces the fault-free
//! step bit-for-bit.
//!
//! Plan grammar (comma-separated rules):
//!
//! ```text
//! plan    := rule ("," rule)*
//! rule    := site ":" tag (":" trigger)+
//! site    := "exec" | "transfer"
//! trigger := "every=K"   fire on every K-th matching call
//!          | "at=N"      fire on the N-th matching call (1-based)
//!          | "n=M"       stop after M fires (default: unlimited)
//! ```
//!
//! e.g. `exec:decode:every=7:n=3,transfer:h2d:at=12`. An `exec` rule's
//! tag matches by substring against the artifact name ("decode" matches
//! every decode artifact; `*` matches everything); `transfer` tags are
//! the fixed direction labels `h2d` and `d2h`. Each rule keeps its own
//! call counter, so a plan is a pure function of the call sequence — no
//! clocks, no RNG — and a chaos test replays identically every run.
//!
//! Error taxonomy (`classify`): injected faults are always transient —
//! the guarded call never ran. Real transfer failures are transient too
//! (a failed upload/fetch consumes no device state). Real execution
//! failures are fatal: the artifact may have consumed its donated cache
//! inputs, so the only safe recovery is the engine's slot-level
//! containment (fail or re-prefill the affected slots over a rebuilt
//! cache), never a blind retry. See `docs/robustness.md`.

use anyhow::{bail, Result};

/// Marker embedded in every injected error message; `classify` keys on
/// it to tell injected faults from real runtime failures.
pub const FAULT_MARKER: &str = "ao-injected-fault";

/// Which runtime boundary a guarded call crosses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// An XLA execution (`run_buffers` / `run_buffers_device`).
    Exec,
    /// A host<->device transfer (`upload` / `fetch_*`).
    Transfer,
}

impl FaultSite {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultSite::Exec => "exec",
            FaultSite::Transfer => "transfer",
        }
    }

    fn parse(s: &str) -> Result<FaultSite> {
        match s {
            "exec" => Ok(FaultSite::Exec),
            "transfer" => Ok(FaultSite::Transfer),
            other => bail!(
                "fault plan: unknown site '{other}' (expected 'exec' or \
                 'transfer')"
            ),
        }
    }
}

/// Whether an error is worth retrying with the same inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    /// No device state was consumed: retry with the same inputs.
    Transient,
    /// The call may have consumed donated buffers (a real execution
    /// failure): retrying is unsound, contain at the slot level.
    Fatal,
}

/// Classify an error raised by a guarded runtime call at `site`.
pub fn classify(site: FaultSite, err: &anyhow::Error) -> FaultClass {
    if format!("{err:#}").contains(FAULT_MARKER) {
        // injected BEFORE the real call: nothing ran, retry is sound
        return FaultClass::Transient;
    }
    match site {
        // a failed upload/fetch consumes no device state
        FaultSite::Transfer => FaultClass::Transient,
        // the executable may have consumed its donated inputs
        FaultSite::Exec => FaultClass::Fatal,
    }
}

/// Retry policy for transient faults (`--fault-retries` /
/// `--fault-backoff-ms` / `--fault-jitter-ms`): up to `retries`
/// re-attempts with exponential backoff starting at `backoff_ms`
/// (doubling per attempt), plus up to `jitter_ms` of deterministic
/// seeded jitter to de-synchronize retry storms across workers.
#[derive(Debug, Clone, Copy)]
pub struct FaultPolicy {
    pub retries: usize,
    pub backoff_ms: u64,
    /// Max extra delay per retry; 0 (the default) disables jitter so
    /// chaos replays stay bit-identical unless explicitly opted in.
    pub jitter_ms: u64,
}

impl Default for FaultPolicy {
    fn default() -> FaultPolicy {
        FaultPolicy { retries: 3, backoff_ms: 10, jitter_ms: 0 }
    }
}

impl FaultPolicy {
    /// Backoff before retry attempt `attempt` (1-based), in ms:
    /// `backoff_ms * 2^(attempt-1)`, saturating.
    pub fn backoff_for(&self, attempt: usize) -> u64 {
        let shift = attempt.saturating_sub(1).min(16) as u32;
        self.backoff_ms.saturating_mul(1u64 << shift)
    }

    /// Jitter for retry `attempt` of a call at (`site`, `tag`), in
    /// `0..=jitter_ms`: an FNV-1a hash of the retry coordinates — no
    /// clock, no RNG — so the same plan replays the same delays, while
    /// distinct sites/tags/attempts spread out instead of thundering in
    /// lockstep.
    pub fn jitter_for(&self, site: FaultSite, tag: &str, attempt: usize) -> u64 {
        if self.jitter_ms == 0 {
            return 0;
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in site.as_str().bytes().chain(tag.bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
        h ^= attempt as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
        h % self.jitter_ms.saturating_add(1)
    }
}

/// Cumulative fault accounting, surfaced in the serving report as
/// `faults[injected retried recovered]`.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FaultStats {
    /// faults the injector fired
    pub injected: u64,
    /// retry attempts after a transient failure
    pub retried: u64,
    /// guarded calls that succeeded after at least one retry
    pub recovered: u64,
}

#[derive(Debug, Clone)]
struct FaultRule {
    site: FaultSite,
    /// substring match against the call tag; "*" matches everything
    tag: String,
    /// fire on every K-th matching call
    every: Option<u64>,
    /// fire on these exact matching-call ordinals (1-based)
    at: Vec<u64>,
    /// stop after this many fires (None = unlimited)
    limit: Option<u64>,
    /// matching calls seen so far
    count: u64,
    /// fires so far
    fired: u64,
}

impl FaultRule {
    fn parse(rule: &str) -> Result<FaultRule> {
        let mut parts = rule.split(':');
        let site = match parts.next() {
            Some(s) if !s.is_empty() => FaultSite::parse(s)?,
            _ => bail!("fault plan: empty rule in '{rule}'"),
        };
        let tag = match parts.next() {
            Some(t) if !t.is_empty() => t.to_string(),
            _ => bail!("fault plan: rule '{rule}' is missing a tag"),
        };
        let mut out = FaultRule {
            site,
            tag,
            every: None,
            at: Vec::new(),
            limit: None,
            count: 0,
            fired: 0,
        };
        let mut has_trigger = false;
        for trig in parts {
            let (key, val) = match trig.split_once('=') {
                Some(kv) => kv,
                None => bail!(
                    "fault plan: trigger '{trig}' in rule '{rule}' is not \
                     key=value"
                ),
            };
            let n: u64 = match val.parse() {
                Ok(n) => n,
                Err(_) => bail!(
                    "fault plan: trigger '{trig}' in rule '{rule}' needs a \
                     number"
                ),
            };
            match key {
                "every" => {
                    if n == 0 {
                        bail!("fault plan: every=0 in rule '{rule}'");
                    }
                    out.every = Some(n);
                    has_trigger = true;
                }
                "at" => {
                    if n == 0 {
                        bail!(
                            "fault plan: at=0 in rule '{rule}' (ordinals \
                             are 1-based)"
                        );
                    }
                    out.at.push(n);
                    has_trigger = true;
                }
                "n" => out.limit = Some(n),
                other => bail!(
                    "fault plan: unknown trigger '{other}' in rule \
                     '{rule}' (expected every=, at=, n=)"
                ),
            }
        }
        if !has_trigger {
            bail!(
                "fault plan: rule '{rule}' has no trigger (add every=K \
                 or at=N)"
            );
        }
        Ok(out)
    }

    fn matches(&self, site: FaultSite, tag: &str) -> bool {
        self.site == site && (self.tag == "*" || tag.contains(&self.tag))
    }

    /// Count one matching call; true when the rule fires on it.
    fn tick(&mut self) -> bool {
        self.count += 1;
        if self.limit.is_some_and(|m| self.fired >= m) {
            return false;
        }
        let hit = self.every.is_some_and(|k| self.count % k == 0)
            || self.at.contains(&self.count);
        if hit {
            self.fired += 1;
        }
        hit
    }
}

/// A parsed fault plan with per-rule call counters.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    rules: Vec<FaultRule>,
    injected: u64,
}

impl FaultInjector {
    /// Parse a fault plan; errors name the offending rule.
    pub fn parse(plan: &str) -> Result<FaultInjector> {
        let mut rules = Vec::new();
        for rule in plan.split(',') {
            let rule = rule.trim();
            if rule.is_empty() {
                continue;
            }
            rules.push(FaultRule::parse(rule)?);
        }
        if rules.is_empty() {
            bail!("fault plan '{plan}' contains no rules");
        }
        Ok(FaultInjector { rules, injected: 0 })
    }

    /// Register a guarded call at (`site`, `tag`); Some(message) when a
    /// fault fires on it. Every matching rule counts the call, so rule
    /// counters are independent of one another.
    pub fn next_fault(
        &mut self,
        site: FaultSite,
        tag: &str,
    ) -> Option<String> {
        let mut fired: Option<String> = None;
        for rule in &mut self.rules {
            if !rule.matches(site, tag) {
                continue;
            }
            if rule.tick() && fired.is_none() {
                self.injected += 1;
                fired = Some(format!(
                    "{FAULT_MARKER}: {}:{tag} call {} (rule {}:{})",
                    site.as_str(),
                    rule.count,
                    rule.site.as_str(),
                    rule.tag
                ));
            }
        }
        fired
    }

    /// Total faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn parses_the_issue_example_plan() {
        let mut inj =
            FaultInjector::parse("exec:decode:every=7:n=3,transfer:h2d:at=12")
                .unwrap();
        // decode execs: calls 7, 14, 21 fire; 28 is past n=3
        let mut fired = Vec::new();
        for call in 1..=30u64 {
            if inj.next_fault(FaultSite::Exec, "decode_f32").is_some() {
                fired.push(call);
            }
        }
        assert_eq!(fired, vec![7, 14, 21]);
        // h2d transfers: exactly call 12 fires
        let mut fired = Vec::new();
        for call in 1..=20u64 {
            if inj.next_fault(FaultSite::Transfer, "h2d").is_some() {
                fired.push(call);
            }
        }
        assert_eq!(fired, vec![12]);
    }

    #[test]
    fn replay_is_deterministic() {
        let plan = "exec:*:every=3:n=5,transfer:d2h:at=2:at=9";
        let calls: Vec<(FaultSite, &str)> = (0..40)
            .map(|i| {
                if i % 2 == 0 {
                    (FaultSite::Exec, "admit_suffix")
                } else {
                    (FaultSite::Transfer, "d2h")
                }
            })
            .collect();
        let run = || {
            let mut inj = FaultInjector::parse(plan).unwrap();
            calls
                .iter()
                .map(|(s, t)| inj.next_fault(*s, t).is_some())
                .collect::<Vec<bool>>()
        };
        assert_eq!(run(), run(), "same plan + same calls = same faults");
    }

    #[test]
    fn counters_are_per_rule_and_tag_matches_substring() {
        let mut inj =
            FaultInjector::parse("exec:decode:at=2,exec:admit:at=1").unwrap();
        // decode calls do not advance the admit rule and vice versa
        assert!(inj.next_fault(FaultSite::Exec, "tiny_decode_f32").is_none());
        assert!(inj.next_fault(FaultSite::Exec, "tiny_admit_s8").is_some());
        assert!(inj.next_fault(FaultSite::Exec, "tiny_decode_f32").is_some());
        assert_eq!(inj.injected(), 2);
        // transfers never match exec rules
        assert!(inj.next_fault(FaultSite::Transfer, "decode").is_none());
    }

    #[test]
    fn rejects_malformed_plans() {
        for bad in [
            "",
            "exec",
            "exec:decode",
            "exec:decode:every=0",
            "exec:decode:at=0",
            "exec:decode:every=x",
            "exec:decode:soon=3",
            "decode:exec:at=1",
            "exec::at=1",
        ] {
            assert!(FaultInjector::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn injected_faults_classify_transient_real_exec_fatal() {
        let mut inj = FaultInjector::parse("exec:decode:at=1").unwrap();
        let msg = inj.next_fault(FaultSite::Exec, "decode").unwrap();
        let injected = anyhow!(msg);
        assert_eq!(classify(FaultSite::Exec, &injected), FaultClass::Transient);
        let real = anyhow!("execute decode_f32: INTERNAL: device error");
        assert_eq!(classify(FaultSite::Exec, &real), FaultClass::Fatal);
        let fetch = anyhow!("fetch buffer: transport closed");
        assert_eq!(
            classify(FaultSite::Transfer, &fetch),
            FaultClass::Transient
        );
    }

    #[test]
    fn backoff_is_exponential_and_saturating() {
        let p = FaultPolicy { retries: 3, backoff_ms: 10, jitter_ms: 0 };
        assert_eq!(p.backoff_for(1), 10);
        assert_eq!(p.backoff_for(2), 20);
        assert_eq!(p.backoff_for(3), 40);
        let big = FaultPolicy {
            retries: 99,
            backoff_ms: u64::MAX,
            jitter_ms: 0,
        };
        assert_eq!(big.backoff_for(64), u64::MAX, "saturates, no overflow");
    }

    #[test]
    fn jitter_is_deterministic_bounded_and_off_by_default() {
        let p = FaultPolicy { jitter_ms: 7, ..Default::default() };
        // pure function of the retry coordinates: replays identically
        for attempt in 1..=8usize {
            let j = p.jitter_for(FaultSite::Exec, "decode_f32", attempt);
            assert!(j <= 7, "jitter {j} exceeds jitter_ms");
            assert_eq!(
                j,
                p.jitter_for(FaultSite::Exec, "decode_f32", attempt)
            );
        }
        // coordinates actually spread: not every attempt collides
        let spread: std::collections::BTreeSet<u64> = (1..=16)
            .map(|a| p.jitter_for(FaultSite::Transfer, "h2d", a))
            .collect();
        assert!(spread.len() > 1, "jitter never varies across attempts");
        // default policy adds nothing — chaos replays stay bit-identical
        let off = FaultPolicy::default();
        assert_eq!(off.jitter_for(FaultSite::Exec, "decode_f32", 1), 0);
    }

    #[test]
    fn fire_limit_caps_every_and_at_together() {
        let mut inj =
            FaultInjector::parse("transfer:h2d:every=2:at=3:n=2").unwrap();
        let fired: Vec<u64> = (1..=10)
            .filter(|_| inj.next_fault(FaultSite::Transfer, "h2d").is_some())
            .collect();
        // call 2 (every), call 3 (at), then the n=2 cap stops the rest
        assert_eq!(fired.len(), 2);
        assert_eq!(inj.injected(), 2);
    }
}
