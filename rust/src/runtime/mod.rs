//! PJRT runtime: manifest-driven artifact loading and execution.
//!
//! `make artifacts` produces `artifacts/manifest.json` + `*.hlo.txt`; this
//! module is the only place that touches the `xla` crate's execution API.
//! Artifacts are compiled lazily and cached; inputs bind positionally in
//! manifest order (== jax pytree flatten order, the aot.py contract).

pub mod artifact;

use crate::tensor::HostTensor;
use anyhow::{anyhow, Context, Result};
use artifact::{ArtifactSpec, Manifest};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// A device buffer together with the host literal backing its (possibly
/// still in-flight) upload. Keep this alive as long as the buffer is used.
pub struct OwnedBuffer {
    _source: Literal,
    pub buffer: PjRtBuffer,
}

pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// cumulative time spent inside XLA execute calls (perf accounting)
    pub xla_seconds: RefCell<f64>,
}

impl Runtime {
    /// Open an artifact directory (must contain manifest.json).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            xla_seconds: RefCell::new(0.0),
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.artifact(name)
    }

    /// Compile (or fetch cached) an executable.
    pub fn load(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        crate::info!(
            "compiled artifact '{name}' in {:.2}s", t0.elapsed().as_secs_f64()
        );
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Upload a literal to a device buffer owned by the caller.
    ///
    /// NOTE 1: the `xla` crate's `execute::<Literal>` path leaks its
    /// internally-created input buffers (xla_rs.cc `execute` releases them
    /// and never frees) — every run through AO goes through `execute_b`
    /// with buffers created here, which ARE dropped.
    ///
    /// NOTE 2: `BufferFromHostLiteral` transfers asynchronously: the
    /// source literal MUST stay alive until the buffer has been consumed
    /// by an execution (or synced). `OwnedBuffer` bundles the two.
    pub fn to_buffer(&self, lit: Literal) -> Result<OwnedBuffer> {
        let buffer = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("upload literal: {e:?}"))?;
        Ok(OwnedBuffer { _source: lit, buffer })
    }

    /// Execute with device-buffer inputs; returns the decomposed output
    /// tuple as host literals. Use this with cached `to_buffer` uploads for
    /// inputs that do not change between calls (weights).
    pub fn run_buffers(
        &self,
        name: &str,
        inputs: &[&PjRtBuffer],
    ) -> Result<Vec<Literal>> {
        let spec = self.manifest.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            anyhow::bail!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute_b::<&PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {name}: {e:?}"))?;
        *self.xla_seconds.borrow_mut() += t0.elapsed().as_secs_f64();
        tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose result {name}: {e:?}"))
    }

    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let bufs: Vec<PjRtBuffer> = inputs
            .iter()
            .map(|l| {
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("upload literal: {e:?}"))
            })
            .collect::<Result<_>>()?;
        // `inputs` outlives the execution below, so the async uploads are
        // safe here without OwnedBuffer.
        let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
        self.run_buffers(name, &refs)
    }

    /// Execute with host tensors (convenience for tests/CLI paths).
    pub fn run_host(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let outs = self.run(name, &lits)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// Validate that host inputs match the manifest spec (debug aid).
    pub fn check_inputs(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<()> {
        let spec = self.manifest.artifact(name)?;
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype().name() != s.dtype {
                anyhow::bail!(
                    "input {i} ('{}') mismatch: artifact wants {:?} {}, got \
                     {:?} {}",
                    s.name, s.shape, s.dtype, t.shape, t.dtype().name()
                );
            }
        }
        Ok(())
    }
}
