//! PJRT runtime: manifest-driven artifact loading and execution.

// ao-lint: allow-file(index) -- buffer/output vectors are indexed right
// after the manifest length checks that size them; panic discipline
// (allow(panic)) is still enforced site-by-site.

//!
//! `make artifacts` produces `artifacts/manifest.json` + `*.hlo.txt`; this
//! module is the only place that touches the `xla` crate's execution API.
//! Artifacts are compiled lazily and cached; inputs bind positionally in
//! manifest order (== jax pytree flatten order, the aot.py contract).
//!
//! Two execution paths, chosen by where the caller wants the outputs:
//!
//! - **Literal path** (`run`, `run_buffers`, `run_host`): every output is
//!   downloaded to a host `Literal`. Right for training steps and eval,
//!   where the host consumes everything anyway.
//! - **Device path** (`run_buffers_device`): outputs stay on device as
//!   owned `PjRtBuffer`s the caller can feed straight back into the next
//!   execution. This is what keeps the serving engine's KV cache resident
//!   across decode steps — only the logits are fetched per token, via
//!   `fetch_output`. See `coordinator::engine` for the dataflow.
//!
//! All host↔device traffic initiated through this module is metered in
//! `TransferStats` (logical payload bytes, not PJRT-padded sizes), so the
//! serving report can prove the decode hot path moves logits only.
//!
//! **Buffer donation.** Artifacts whose manifest entry carries a
//! `donate` list (decode and admit: the KV cache arguments) are compiled
//! with an `input_output_alias` injected into their HLO header, so XLA
//! reuses the input cache allocation for the output instead of
//! alloc+free per step. Support is discovered by a one-time capability
//! probe (`donation_supported`); when the parser or PJRT client rejects
//! the annotation, the artifact silently falls back to the plain copy
//! path — identical results, two extra allocations per step.

pub mod artifact;
pub mod faults;

use crate::tensor::HostTensor;
use crate::util::stats::GraphStat;
use crate::xb::{
    HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};
use anyhow::{anyhow, Context, Result};
use artifact::{ArtifactSpec, Manifest};
use faults::{FaultClass, FaultInjector, FaultPolicy, FaultSite, FaultStats};
use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

/// A device buffer together with the host literal backing its (possibly
/// still in-flight) upload. Keep this alive as long as the buffer is used.
/// Buffers produced by an execution have no host source (`from_device`).
pub struct OwnedBuffer {
    _source: Option<Literal>,
    /// memory-ledger stake released when the buffer drops (`upload_cat`)
    _ledger: Option<LedgerEntry>,
    pub buffer: PjRtBuffer,
}

impl OwnedBuffer {
    /// Wrap an execution output: device-resident, no host backing needed.
    pub fn from_device(buffer: PjRtBuffer) -> OwnedBuffer {
        OwnedBuffer { _source: None, _ledger: None, buffer }
    }
}

/// Device-memory ledger category: what a resident byte is *for*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemCat {
    /// model parameters uploaded once and held for the engine's lifetime
    Weights,
    /// KV cache pages (the paged token cache itself)
    KvPages,
    /// per-page quantization scale tensors riding alongside the KV pages
    ScalePages,
    /// transient execution inputs (token ids, lengths, block tables, ...)
    Io,
    /// host-side trace ring capacity, counted so telemetry overhead is
    /// attributed rather than invisible
    Trace,
}

impl MemCat {
    pub fn as_str(self) -> &'static str {
        match self {
            MemCat::Weights => "weights",
            MemCat::KvPages => "kv_pages",
            MemCat::ScalePages => "scale_pages",
            MemCat::Io => "io",
            MemCat::Trace => "trace",
        }
    }

    fn idx(self) -> usize {
        match self {
            MemCat::Weights => 0,
            MemCat::KvPages => 1,
            MemCat::ScalePages => 2,
            MemCat::Io => 3,
            MemCat::Trace => 4,
        }
    }
}

/// Point-in-time copy of the ledger counters. `total` is maintained
/// independently of the per-category cells, so "categories sum to total"
/// is an arithmetic-consistency check, not an identity.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MemSnapshot {
    pub weights: u64,
    pub kv_pages: u64,
    pub scale_pages: u64,
    pub io: u64,
    pub trace: u64,
    pub total: u64,
}

impl MemSnapshot {
    /// Sum of the per-category counters (cross-check against `total`).
    pub fn category_sum(&self) -> u64 {
        self.weights + self.kv_pages + self.scale_pages + self.io + self.trace
    }
}

#[derive(Default)]
struct LedgerInner {
    by_cat: [u64; 5],
    total: u64,
}

/// Shared device-memory ledger. Every resident byte is staked by a
/// [`LedgerEntry`] whose `Drop` returns it, so the counters track live
/// allocations, not cumulative traffic. Cheap to clone (shared cell).
#[derive(Clone, Default)]
pub struct MemLedger {
    inner: Rc<RefCell<LedgerInner>>,
}

impl MemLedger {
    /// Stake `bytes` against `cat`; released when the entry drops.
    pub fn entry(&self, cat: MemCat, bytes: u64) -> LedgerEntry {
        {
            let mut inner = self.inner.borrow_mut();
            inner.by_cat[cat.idx()] += bytes;
            inner.total += bytes;
        }
        LedgerEntry { ledger: self.clone(), cat, bytes }
    }

    pub fn snapshot(&self) -> MemSnapshot {
        let inner = self.inner.borrow();
        MemSnapshot {
            weights: inner.by_cat[MemCat::Weights.idx()],
            kv_pages: inner.by_cat[MemCat::KvPages.idx()],
            scale_pages: inner.by_cat[MemCat::ScalePages.idx()],
            io: inner.by_cat[MemCat::Io.idx()],
            trace: inner.by_cat[MemCat::Trace.idx()],
            total: inner.total,
        }
    }

    fn release(&self, cat: MemCat, bytes: u64) {
        let mut inner = self.inner.borrow_mut();
        inner.by_cat[cat.idx()] =
            inner.by_cat[cat.idx()].saturating_sub(bytes);
        inner.total = inner.total.saturating_sub(bytes);
    }
}

/// RAII stake in a [`MemLedger`]: `bytes` stay attributed to `cat` until
/// this entry drops.
pub struct LedgerEntry {
    ledger: MemLedger,
    cat: MemCat,
    bytes: u64,
}

impl Drop for LedgerEntry {
    fn drop(&mut self) {
        self.ledger.release(self.cat, self.bytes);
    }
}

/// Cumulative host↔device transfer accounting (logical payload bytes).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TransferStats {
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

/// One transient-fault retry the runtime slept for; the engine drains
/// these per step into the trace (`TraceEvent::Retry`).
#[derive(Debug, Clone)]
pub struct RetryRecord {
    pub site: &'static str,
    pub tag: String,
    /// 1-based retry attempt
    pub attempt: usize,
    /// exponential-backoff portion of the delay, ms
    pub backoff_ms: u64,
    /// deterministic jitter portion of the delay, ms
    pub jitter_ms: u64,
}

/// Retry-log bound: recording stops (deterministically) past this many
/// undrained retries, so an un-traced run never grows the log unbounded.
const RETRY_LOG_CAP: usize = 1024;

pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// cumulative time spent inside XLA execute calls (perf accounting)
    pub xla_seconds: RefCell<f64>,
    transfers: RefCell<TransferStats>,
    /// artifacts that already warned about the packed-tuple fallback
    warned_packed: RefCell<std::collections::HashSet<String>>,
    /// one-time capability probe result: does this parser/client accept
    /// `input_output_alias` (buffer donation)?
    donation_ok: Cell<Option<bool>>,
    /// one-time capability probe result: does `execute_b` return one
    /// buffer per tuple element (untupled outputs)? When it does, the
    /// packed-tuple host round-trip in `run_buffers_device` can never be
    /// the path taken.
    untuple_ok: Cell<Option<bool>>,
    /// artifacts whose executable was compiled with cache donation
    donated: RefCell<std::collections::HashSet<String>>,
    /// optional deterministic fault plan (chaos testing); consulted
    /// immediately before every execute/transfer call
    faults: RefCell<Option<FaultInjector>>,
    /// retry/backoff policy for transient execute/transfer failures
    fault_policy: Cell<FaultPolicy>,
    /// cumulative injection/retry/recovery accounting
    fault_stats: RefCell<FaultStats>,
    /// undrained per-retry delay records (bounded by `RETRY_LOG_CAP`)
    retry_log: RefCell<Vec<RetryRecord>>,
    /// append-only copy of the retry records (also bounded by
    /// `RETRY_LOG_CAP`), never drained — the postmortem bundle's feed
    retry_history: RefCell<Vec<RetryRecord>>,
    /// retries the bounded drainable log had no room for (telemetry loss)
    retry_log_dropped: Cell<u64>,
    /// cumulative jitter slept across all retries, ms
    jitter_slept_ms: Cell<u64>,
    /// live device-memory attribution (see `MemCat`)
    ledger: MemLedger,
    /// per-artifact execution profile: calls, cumulative host-timed
    /// exec_us, latency histogram (keyed by artifact name so device-event
    /// timing can replace the source without changing consumers)
    graph_profile: RefCell<BTreeMap<String, GraphStat>>,
}

impl Runtime {
    /// Open an artifact directory (must contain manifest.json).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            xla_seconds: RefCell::new(0.0),
            transfers: RefCell::new(TransferStats::default()),
            warned_packed: RefCell::new(std::collections::HashSet::new()),
            donation_ok: Cell::new(None),
            untuple_ok: Cell::new(None),
            donated: RefCell::new(std::collections::HashSet::new()),
            faults: RefCell::new(None),
            fault_policy: Cell::new(FaultPolicy::default()),
            fault_stats: RefCell::new(FaultStats::default()),
            retry_log: RefCell::new(Vec::new()),
            retry_history: RefCell::new(Vec::new()),
            retry_log_dropped: Cell::new(0),
            jitter_slept_ms: Cell::new(0),
            ledger: MemLedger::default(),
            graph_profile: RefCell::new(BTreeMap::new()),
        })
    }

    /// Install (or clear) a fault injector and the retry policy for
    /// transient failures. The engine installs the parsed `--fault-plan`
    /// AFTER startup uploads complete, so load-time traffic is never
    /// faulted.
    pub fn install_faults(
        &self,
        inj: Option<FaultInjector>,
        policy: FaultPolicy,
    ) {
        *self.faults.borrow_mut() = inj;
        self.fault_policy.set(policy);
    }

    /// Snapshot of the cumulative fault counters.
    pub fn fault_stats(&self) -> FaultStats {
        *self.fault_stats.borrow()
    }

    /// Take the undrained per-retry delay records (trace feed).
    pub fn drain_retries(&self) -> Vec<RetryRecord> {
        std::mem::take(&mut *self.retry_log.borrow_mut())
    }

    /// Retries the bounded drainable log could not record.
    pub fn retry_log_dropped(&self) -> u64 {
        self.retry_log_dropped.get()
    }

    /// Copy of the append-only retry history (postmortem feed; bounded by
    /// `RETRY_LOG_CAP`, never drained).
    pub fn retry_history(&self) -> Vec<RetryRecord> {
        self.retry_history.borrow().clone()
    }

    /// Total jitter slept across all retries so far, ms.
    pub fn jitter_slept_ms(&self) -> u64 {
        self.jitter_slept_ms.get()
    }

    /// The shared device-memory ledger (clone it to stake entries).
    pub fn ledger(&self) -> &MemLedger {
        &self.ledger
    }

    /// Snapshot of the live device-memory attribution.
    pub fn mem_snapshot(&self) -> MemSnapshot {
        self.ledger.snapshot()
    }

    /// Per-artifact execution profile, hottest (most cumulative exec
    /// time) first.
    pub fn graph_stats(&self) -> Vec<GraphStat> {
        let mut stats: Vec<GraphStat> =
            self.graph_profile.borrow().values().cloned().collect();
        stats.sort_by(|a, b| b.exec_us.cmp(&a.exec_us));
        stats
    }

    /// Fold one timed execution of `name` into the per-graph profile.
    fn note_graph(&self, name: &str, seconds: f64) {
        let mut prof = self.graph_profile.borrow_mut();
        let stat = prof.entry(name.to_string()).or_insert_with(|| GraphStat {
            name: name.to_string(),
            calls: 0,
            exec_us: 0,
            hist: crate::util::stats::LogHistogram::new(),
        });
        stat.calls += 1;
        stat.exec_us += (seconds * 1e6) as u64;
        stat.hist.record(seconds);
    }

    /// Run a guarded execute/transfer call under the fault policy:
    /// consult the injector first (an injected fault fails the attempt
    /// WITHOUT running `f`, which is what makes retrying it sound), then
    /// retry transient failures with exponential backoff until the
    /// policy's retry budget is spent. Real execution failures classify
    /// fatal — the call may have consumed donated buffers — and surface
    /// immediately for slot-level containment in the engine.
    fn with_faults<T>(
        &self,
        site: FaultSite,
        tag: &str,
        mut f: impl FnMut() -> Result<T>,
    ) -> Result<T> {
        let policy = self.fault_policy.get();
        let mut attempt = 0usize;
        loop {
            let injected = self
                .faults
                .borrow_mut()
                .as_mut()
                .and_then(|inj| inj.next_fault(site, tag));
            let result = match injected {
                Some(msg) => {
                    self.fault_stats.borrow_mut().injected += 1;
                    Err(anyhow!(msg))
                }
                None => f(),
            };
            let err = match result {
                Ok(v) => {
                    if attempt > 0 {
                        self.fault_stats.borrow_mut().recovered += 1;
                    }
                    return Ok(v);
                }
                Err(err) => err,
            };
            let transient =
                faults::classify(site, &err) == FaultClass::Transient;
            if !transient || attempt >= policy.retries {
                return Err(err);
            }
            attempt += 1;
            self.fault_stats.borrow_mut().retried += 1;
            let backoff = policy.backoff_for(attempt);
            let jitter = policy.jitter_for(site, tag, attempt);
            self.jitter_slept_ms.set(
                self.jitter_slept_ms.get().saturating_add(jitter),
            );
            let rec = RetryRecord {
                site: site.as_str(),
                tag: tag.to_string(),
                attempt,
                backoff_ms: backoff,
                jitter_ms: jitter,
            };
            {
                let mut log = self.retry_log.borrow_mut();
                if log.len() < RETRY_LOG_CAP {
                    log.push(rec.clone());
                } else {
                    // telemetry loss must be visible, not silent: the
                    // report/exposition surfaces this counter
                    self.retry_log_dropped
                        .set(self.retry_log_dropped.get() + 1);
                }
            }
            {
                let mut hist = self.retry_history.borrow_mut();
                if hist.len() < RETRY_LOG_CAP {
                    hist.push(rec);
                }
            }
            let ms = backoff.saturating_add(jitter);
            if ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.artifact(name)
    }

    /// Snapshot of the cumulative transfer counters.
    pub fn transfer_stats(&self) -> TransferStats {
        *self.transfers.borrow()
    }

    fn note_h2d(&self, bytes: usize) {
        self.transfers.borrow_mut().h2d_bytes += bytes as u64;
    }

    fn note_d2h(&self, bytes: usize) {
        self.transfers.borrow_mut().d2h_bytes += bytes as u64;
    }

    /// Compile (or fetch cached) an executable. When the manifest declares
    /// donation pairs for the artifact and the capability probe passes,
    /// the HLO is compiled with the aliases injected; any failure on that
    /// path falls back to the plain (copy) compilation.
    pub fn load(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let mut exe = None;
        if !spec.donate.is_empty() && self.donation_supported() {
            let attempt = std::fs::read_to_string(&path)
                .with_context(|| format!("read HLO {}", path.display()))
                .and_then(|text| inject_input_output_alias(&text, &spec.donate))
                .and_then(|aliased| self.compile_text(&aliased, name));
            match attempt {
                Ok(e) => {
                    self.donated.borrow_mut().insert(name.to_string());
                    exe = Some(e);
                }
                Err(err) => crate::warn!(
                    "artifact '{name}': donation rejected ({err:#}); \
                     falling back to the copy path"
                ),
            }
        }
        let exe = match exe {
            Some(e) => e,
            None => self.compile_file(&path, name)?,
        };
        crate::info!(
            "compiled artifact '{name}' in {:.2}s{}",
            t0.elapsed().as_secs_f64(),
            if self.donation_active(name) { " (cache donated)" } else { "" }
        );
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn compile_file(
        &self,
        path: &Path,
        name: &str,
    ) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse HLO {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))
    }

    /// Compile HLO text. The binding only parses from a file, so the text
    /// takes a detour through a temp file — keyed by pid AND a process-wide
    /// counter, because parallel test harnesses run several `Runtime`s in
    /// one process and a (pid, name)-only path would race write/parse
    /// against remove.
    fn compile_text(
        &self,
        text: &str,
        name: &str,
    ) -> Result<PjRtLoadedExecutable> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = std::env::temp_dir().join(format!(
            "ao_hlo_{}_{}_{name}.hlo.txt",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, text)
            .with_context(|| format!("write {}", tmp.display()))?;
        let out = self.compile_file(&tmp, name);
        let _ = std::fs::remove_file(&tmp);
        out
    }

    /// Whether this parser + PJRT client accept `input_output_alias`
    /// (buffer donation). Probed once by compiling a minimal aliased
    /// module; `AO_NO_DONATION=1` forces the copy path.
    pub fn donation_supported(&self) -> bool {
        if crate::util::env::var("AO_NO_DONATION").is_some_and(|v| v == "1") {
            return false;
        }
        if let Some(ok) = self.donation_ok.get() {
            return ok;
        }
        let ok = self.compile_text(DONATION_PROBE_HLO, "donation_probe").is_ok();
        if !ok {
            crate::warn!(
                "input_output_alias probe failed; decode/admit run without \
                 buffer donation (alloc+free per step)"
            );
        }
        self.donation_ok.set(Some(ok));
        ok
    }

    /// Whether `name` was compiled with its cache arguments donated.
    pub fn donation_active(&self, name: &str) -> bool {
        self.donated.borrow().contains(name)
    }

    /// Whether the binding's execute path returns one device buffer per
    /// output tuple element (the `ExecuteOptions.untuple_result`
    /// behavior). Probed once by running a minimal two-output module:
    /// when this holds, `run_buffers_device` keeps every output on
    /// device and the metered packed-tuple fallback is provably dead
    /// code for this process — the
    /// `decode_host_traffic_is_logits_only` /
    /// `admission_host_traffic_is_rows_only` integration gates then pin
    /// the transfer totals the untupled path implies.
    pub fn untupled_outputs(&self) -> bool {
        if let Some(ok) = self.untuple_ok.get() {
            return ok;
        }
        let ok = self.probe_untuple().unwrap_or(false);
        if !ok {
            crate::warn!(
                "execute returns packed tuple outputs; device-resident \
                 decode/admission degrade to metered host round-trips"
            );
        }
        self.untuple_ok.set(Some(ok));
        ok
    }

    fn probe_untuple(&self) -> Result<bool> {
        let exe = self.compile_text(UNTUPLE_PROBE_HLO, "untuple_probe")?;
        // unmetered: probe traffic is not workload traffic
        let input = HostTensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        let buf = self.to_buffer(input.to_literal()?)?;
        let result = exe
            .execute_b::<&PjRtBuffer>(&[&buf.buffer])
            .map_err(|e| anyhow!("untuple probe execute: {e:?}"))?;
        Ok(result.first().map_or(false, |outs| outs.len() == 2))
    }

    /// Upload a literal to a device buffer owned by the caller.
    ///
    /// NOTE 1: the `xla` crate's `execute::<Literal>` path leaks its
    /// internally-created input buffers (xla_rs.cc `execute` releases them
    /// and never frees) — every run through AO goes through `execute_b`
    /// with buffers created here, which ARE dropped.
    ///
    /// NOTE 2: `BufferFromHostLiteral` transfers asynchronously: the
    /// source literal MUST stay alive until the buffer has been consumed
    /// by an execution (or synced). `OwnedBuffer` bundles the two.
    ///
    /// This raw path is not metered (the literal's size is opaque here);
    /// prefer `upload` when the source is a `HostTensor`.
    pub fn to_buffer(&self, lit: Literal) -> Result<OwnedBuffer> {
        let buffer = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("upload literal: {e:?}"))?;
        Ok(OwnedBuffer { _source: Some(lit), _ledger: None, buffer })
    }

    /// Upload a host tensor, counting its bytes as H2D traffic and
    /// staking them in the memory ledger as transient `io`. Guarded by
    /// the fault policy (site `transfer`, tag `h2d`); the meter only
    /// counts the attempt that succeeds.
    pub fn upload(&self, t: &HostTensor) -> Result<OwnedBuffer> {
        self.upload_cat(t, MemCat::Io)
    }

    /// `upload` with an explicit ledger category: the uploaded bytes stay
    /// attributed to `cat` until the returned buffer drops. Long-lived
    /// allocations whose buffers are *replaced* in place (the donated KV
    /// cache) should instead hold a standalone [`MemLedger::entry`] and
    /// upload through `upload_raw`.
    pub fn upload_cat(
        &self,
        t: &HostTensor,
        cat: MemCat,
    ) -> Result<OwnedBuffer> {
        let mut buf = self.upload_raw(t)?;
        buf._ledger = Some(self.ledger.entry(cat, t.byte_size() as u64));
        Ok(buf)
    }

    /// Metered, fault-guarded upload WITHOUT a ledger stake: for
    /// (re-)uploads of an allocation whose residency is already staked by
    /// a standalone [`MemLedger::entry`] — the KV cache zeros and the
    /// host-splice mirror, whose buffers are replaced wholesale while
    /// the logical allocation stays resident.
    pub fn upload_raw(&self, t: &HostTensor) -> Result<OwnedBuffer> {
        self.with_faults(FaultSite::Transfer, "h2d", || {
            let buf = self.to_buffer(t.to_literal()?)?;
            self.note_h2d(t.byte_size());
            Ok(buf)
        })
    }

    /// Download one device buffer to a host literal, counting `bytes` of
    /// D2H traffic (the caller knows the logical payload size). Guarded
    /// by the fault policy (site `transfer`, tag `d2h`).
    pub fn fetch_sized(
        &self,
        buf: &PjRtBuffer,
        bytes: usize,
    ) -> Result<Literal> {
        self.with_faults(FaultSite::Transfer, "d2h", || {
            self.fetch_sized_inner(buf, bytes)
        })
    }

    fn fetch_sized_inner(
        &self,
        buf: &PjRtBuffer,
        bytes: usize,
    ) -> Result<Literal> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch buffer: {e:?}"))?;
        self.note_d2h(bytes);
        Ok(lit)
    }

    /// Download a device buffer as a host tensor, metered by the actual
    /// payload size (works for any dtype the tensor layer knows).
    /// Guarded by the fault policy (site `transfer`, tag `d2h`).
    pub fn fetch_tensor(&self, buf: &PjRtBuffer) -> Result<HostTensor> {
        self.with_faults(FaultSite::Transfer, "d2h", || {
            let lit = buf
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch buffer: {e:?}"))?;
            let t = HostTensor::from_literal(&lit)?;
            self.note_d2h(t.byte_size());
            Ok(t)
        })
    }

    /// Download output `idx` of artifact `name`, metered with the size
    /// the manifest declares for that output.
    pub fn fetch_output(
        &self,
        name: &str,
        idx: usize,
        buf: &PjRtBuffer,
    ) -> Result<Literal> {
        let spec = self.manifest.artifact(name)?;
        let io = spec.outputs.get(idx).ok_or_else(|| {
            anyhow!("artifact '{name}' has no output {idx}")
        })?;
        self.fetch_sized(buf, io.byte_size().unwrap_or(0))
    }

    fn check_arity(&self, spec: &ArtifactSpec, n_inputs: usize) -> Result<()> {
        if n_inputs != spec.inputs.len() {
            anyhow::bail!(
                "artifact '{}' expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                n_inputs
            );
        }
        Ok(())
    }

    /// Execute with device-buffer inputs; returns all outputs as host
    /// literals. Use this with cached `upload`s for inputs that do not
    /// change between calls (weights). Handles both binding behaviors:
    /// per-element output buffers, or the whole tuple packed into one
    /// buffer (decomposed on host after download). Guarded by the fault
    /// policy (site `exec`, tag = artifact name); only an *injected*
    /// fault is retried — it fires before the executable runs, so no
    /// donated input was consumed.
    pub fn run_buffers(
        &self,
        name: &str,
        inputs: &[&PjRtBuffer],
    ) -> Result<Vec<Literal>> {
        self.with_faults(FaultSite::Exec, name, || {
            self.run_buffers_inner(name, inputs)
        })
    }

    fn run_buffers_inner(
        &self,
        name: &str,
        inputs: &[&PjRtBuffer],
    ) -> Result<Vec<Literal>> {
        let spec = self.manifest.artifact(name)?;
        self.check_arity(spec, inputs.len())?;
        let n_out = spec.outputs.len();
        let fetched: usize =
            spec.outputs.iter().filter_map(|s| s.byte_size()).sum();
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute_b::<&PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        if result.is_empty() || result[0].is_empty() {
            anyhow::bail!("execute {name}: no output buffers");
        }
        let outs = &result[0];
        let lits = if outs.len() == n_out && n_out > 1 {
            // binding untupled the result: download each element
            outs.iter()
                .map(|b| {
                    b.to_literal_sync()
                        .map_err(|e| anyhow!("fetch result {name}: {e:?}"))
                })
                .collect::<Result<Vec<Literal>>>()?
        } else {
            let mut tuple = outs[0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result {name}: {e:?}"))?;
            match tuple.decompose_tuple() {
                Ok(parts) => parts,
                // a single-output artifact may come back as a bare array
                Err(_) if n_out == 1 => vec![tuple],
                Err(e) => {
                    return Err(anyhow!("decompose result {name}: {e:?}"))
                }
            }
        };
        let secs = t0.elapsed().as_secs_f64();
        *self.xla_seconds.borrow_mut() += secs;
        self.note_graph(name, secs);
        self.note_d2h(fetched);
        Ok(lits)
    }

    /// Execute with device-buffer inputs; outputs STAY on device and are
    /// returned as owned buffers in manifest output order. No host
    /// transfer happens here — callers fetch the (usually few, small)
    /// outputs they need via `fetch_output` and feed the rest back into
    /// the next execution. This is the serving engine's decode hot path.
    ///
    /// If the binding hands back the whole output tuple as one packed
    /// buffer instead of per-element buffers, fall back to a single
    /// (metered) host round-trip to split it — correct everywhere, fast
    /// where the binding cooperates.
    ///
    /// Guarded by the fault policy (site `exec`, tag = artifact name).
    /// Injected faults fire before the executable runs (retry sound);
    /// real execution failures classify fatal because the donated cache
    /// inputs may already be consumed.
    pub fn run_buffers_device(
        &self,
        name: &str,
        inputs: &[&PjRtBuffer],
    ) -> Result<Vec<OwnedBuffer>> {
        self.with_faults(FaultSite::Exec, name, || {
            self.run_buffers_device_inner(name, inputs)
        })
    }

    fn run_buffers_device_inner(
        &self,
        name: &str,
        inputs: &[&PjRtBuffer],
    ) -> Result<Vec<OwnedBuffer>> {
        let spec = self.manifest.artifact(name)?;
        self.check_arity(spec, inputs.len())?;
        let n_out = spec.outputs.len();
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let mut result = exe
            .execute_b::<&PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let secs = t0.elapsed().as_secs_f64();
        *self.xla_seconds.borrow_mut() += secs;
        self.note_graph(name, secs);
        if result.is_empty() || result[0].is_empty() {
            anyhow::bail!("execute {name}: no output buffers");
        }
        let outs = result.swap_remove(0);
        if outs.len() == n_out {
            return Ok(outs.into_iter().map(OwnedBuffer::from_device).collect());
        }
        if outs.len() == 1 && n_out > 1 {
            // Packed tuple: one round-trip, split on host, re-upload.
            // Correct, but it defeats device residency — every output
            // (including large caches) crosses the host boundary. Warn
            // once per artifact so a degraded transfer metric has an
            // explanation in the log.
            if self.warned_packed.borrow_mut().insert(name.to_string()) {
                crate::warn!(
                    "artifact '{name}': binding returned a packed tuple; \
                     device-resident outputs degrade to a host round-trip"
                );
            }
            let total: usize =
                spec.outputs.iter().filter_map(|s| s.byte_size()).sum();
            // unguarded fetch: the executable already ran, so a nested
            // injected transfer fault must not make this exec attempt
            // look retryable
            let mut tuple = self.fetch_sized_inner(&outs[0], total)?;
            let parts = tuple
                .decompose_tuple()
                .map_err(|e| anyhow!("decompose result {name}: {e:?}"))?;
            if parts.len() != n_out {
                anyhow::bail!(
                    "artifact '{name}' tuple has {} elements, manifest \
                     declares {n_out}",
                    parts.len()
                );
            }
            return parts
                .into_iter()
                .zip(&spec.outputs)
                .map(|(lit, io)| {
                    self.note_h2d(io.byte_size().unwrap_or(0));
                    self.to_buffer(lit)
                })
                .collect();
        }
        anyhow::bail!(
            "artifact '{name}' returned {} buffers, manifest declares {n_out}",
            outs.len()
        )
    }

    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let spec = self.manifest.artifact(name)?;
        let uploaded: usize =
            spec.inputs.iter().filter_map(|s| s.byte_size()).sum();
        let bufs: Vec<PjRtBuffer> = inputs
            .iter()
            .map(|l| {
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("upload literal: {e:?}"))
            })
            .collect::<Result<_>>()?;
        self.note_h2d(uploaded);
        // `inputs` outlives the execution below, so the async uploads are
        // safe here without OwnedBuffer.
        let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
        self.run_buffers(name, &refs)
    }

    /// Execute with host tensors (convenience for tests/CLI paths).
    pub fn run_host(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let outs = self.run(name, &lits)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// Validate that host inputs match the manifest spec (debug aid).
    pub fn check_inputs(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<()> {
        let spec = self.manifest.artifact(name)?;
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype().name() != s.dtype {
                anyhow::bail!(
                    "input {i} ('{}') mismatch: artifact wants {:?} {}, got \
                     {:?} {}",
                    s.name, s.shape, s.dtype, t.shape, t.dtype().name()
                );
            }
        }
        Ok(())
    }
}

/// Minimal module with an input-output alias: compiles iff the HLO parser
/// and the PJRT client both accept donation annotations.
const DONATION_PROBE_HLO: &str = "\
HloModule ao_donation_probe, input_output_alias={ {0}: (0, {}, may-alias) }

ENTRY main {
  p0 = f32[4]{0} parameter(0)
  a0 = f32[4]{0} add(p0, p0)
  ROOT t0 = (f32[4]{0}) tuple(a0)
}
";

/// Minimal two-output module: executed once to observe whether the
/// binding hands back one buffer per tuple element or a single packed
/// tuple buffer (the untupled behavior is what keeps the serving cache
/// device-resident).
const UNTUPLE_PROBE_HLO: &str = "\
HloModule ao_untuple_probe

ENTRY main {
  p0 = f32[4]{0} parameter(0)
  a0 = f32[4]{0} add(p0, p0)
  m0 = f32[4]{0} multiply(p0, p0)
  ROOT t0 = (f32[4]{0}, f32[4]{0}) tuple(a0, m0)
}
";

/// Rewrite the `HloModule` header line to carry an `input_output_alias`
/// attribute for the given `(output_tuple_index, parameter_number)` pairs.
/// Text already carrying an alias (a future exporter may bake it in) is
/// returned unchanged.
fn inject_input_output_alias(
    text: &str,
    pairs: &[(usize, usize)],
) -> Result<String> {
    let nl = text.find('\n').context("empty HLO text")?;
    let header = &text[..nl];
    if !header.starts_with("HloModule") {
        anyhow::bail!("HLO text does not start with an HloModule header");
    }
    if header.contains("input_output_alias") {
        return Ok(text.to_string());
    }
    let alias = pairs
        .iter()
        .map(|(out, input)| format!("{{{out}}}: ({input}, {{}}, may-alias)"))
        .collect::<Vec<_>>()
        .join(", ");
    Ok(format!(
        "{header}, input_output_alias={{ {alias} }}{}",
        &text[nl..]
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alias_injection_rewrites_header_only() {
        let text = "HloModule decode_f32\n\nENTRY main {\n}\n";
        let out =
            inject_input_output_alias(text, &[(1, 17), (2, 18)]).unwrap();
        let header = out.lines().next().unwrap();
        assert_eq!(
            header,
            "HloModule decode_f32, input_output_alias={ {1}: (17, {}, \
             may-alias), {2}: (18, {}, may-alias) }"
        );
        // body untouched
        assert!(out.ends_with("\n\nENTRY main {\n}\n"));
    }

    #[test]
    fn alias_injection_keeps_existing_attributes() {
        let text = "HloModule m, entry_computation_layout={(f32[2]{0})->\
                    f32[2]{0}}\nENTRY main {\n}\n";
        let out = inject_input_output_alias(text, &[(0, 0)]).unwrap();
        assert!(out.starts_with(
            "HloModule m, entry_computation_layout={(f32[2]{0})->f32[2]{0}}, \
             input_output_alias={ {0}: (0, {}, may-alias) }"
        ));
    }

    #[test]
    fn alias_injection_is_idempotent() {
        let text = "HloModule m, input_output_alias={ {0}: (0, {}, \
                    may-alias) }\nENTRY main {\n}\n";
        let out = inject_input_output_alias(text, &[(1, 3)]).unwrap();
        assert_eq!(out, text, "pre-aliased text passes through unchanged");
    }

    #[test]
    fn alias_injection_rejects_non_hlo() {
        assert!(inject_input_output_alias("", &[(0, 0)]).is_err());
        assert!(
            inject_input_output_alias("func @main()\n", &[(0, 0)]).is_err()
        );
    }

    #[test]
    fn donation_probe_hlo_is_well_formed() {
        // the probe itself must carry the annotation the probe tests for
        assert!(DONATION_PROBE_HLO.starts_with("HloModule"));
        assert!(DONATION_PROBE_HLO.contains("input_output_alias"));
        assert!(DONATION_PROBE_HLO.contains("ROOT"));
    }

    #[test]
    fn ledger_entries_drop_back_to_zero() {
        let ledger = MemLedger::default();
        let w = ledger.entry(MemCat::Weights, 4096);
        let k = ledger.entry(MemCat::KvPages, 1 << 20);
        let s = ledger.entry(MemCat::ScalePages, 512);
        let io = ledger.entry(MemCat::Io, 64);
        let snap = ledger.snapshot();
        assert_eq!(snap.weights, 4096);
        assert_eq!(snap.kv_pages, 1 << 20);
        assert_eq!(snap.scale_pages, 512);
        assert_eq!(snap.io, 64);
        assert_eq!(snap.trace, 0);
        assert_eq!(snap.total, snap.category_sum(), "independent total");
        drop(io);
        assert_eq!(ledger.snapshot().io, 0, "drop releases the stake");
        drop((w, k, s));
        let end = ledger.snapshot();
        assert_eq!(end.total, 0);
        assert_eq!(end.category_sum(), 0);
    }

    #[test]
    fn ledger_sum_matches_total_under_churn() {
        let ledger = MemLedger::default();
        let _hold = ledger.entry(MemCat::Trace, 96 * 4096);
        for i in 0..100u64 {
            let a = ledger.entry(MemCat::Io, i * 7);
            let b = ledger.entry(MemCat::KvPages, i * 13);
            let snap = ledger.snapshot();
            assert_eq!(snap.total, snap.category_sum());
            drop(a);
            drop(b);
        }
        let snap = ledger.snapshot();
        assert_eq!(snap.total, 96 * 4096);
        assert_eq!(snap.total, snap.category_sum());
    }

    #[test]
    fn mem_cat_names_are_stable() {
        // the report's mem[...] keys and the Prometheus category labels
        // are this enum's strings; renaming one is a breaking change
        let names: Vec<&str> = [
            MemCat::Weights,
            MemCat::KvPages,
            MemCat::ScalePages,
            MemCat::Io,
            MemCat::Trace,
        ]
        .into_iter()
        .map(MemCat::as_str)
        .collect();
        assert_eq!(
            names,
            vec!["weights", "kv_pages", "scale_pages", "io", "trace"]
        );
    }

    #[test]
    fn untuple_probe_hlo_is_well_formed() {
        // the probe must produce a genuine multi-element tuple, or a
        // binding that always packs would still "pass" with one buffer
        assert!(UNTUPLE_PROBE_HLO.starts_with("HloModule"));
        assert!(UNTUPLE_PROBE_HLO
            .contains("ROOT t0 = (f32[4]{0}, f32[4]{0}) tuple(a0, m0)"));
    }
}
