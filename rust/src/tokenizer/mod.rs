//! Tokenization substrate: a byte-level base vocabulary plus a trainable
//! BPE layer (the repo's stand-in for Llama's tokenizer; DESIGN.md §3).
//!
//! Token ids: 0 = PAD, 1 = BOS, 2 = EOS, 3..259 = raw bytes, 259.. = BPE
//! merges. The merge table is trained greedily on the synthetic corpus and
//! serialized as JSON so the Rust server and eval harness share one vocab.

use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const BYTE_BASE: u32 = 3;
pub const N_SPECIAL: u32 = 3;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// merge list in training order: (left, right) -> new id.
    pub merges: Vec<(u32, u32)>,
    merge_map: BTreeMap<(u32, u32), u32>,
    pub vocab_size: usize,
}

impl Tokenizer {
    /// Byte-level tokenizer with no merges (vocab 259).
    pub fn byte_level() -> Tokenizer {
        Tokenizer {
            merges: Vec::new(),
            merge_map: BTreeMap::new(),
            vocab_size: (BYTE_BASE + 256) as usize,
        }
    }

    /// Train `n_merges` BPE merges on the corpus (greedy highest-frequency
    /// adjacent-pair, the standard algorithm).
    pub fn train(corpus: &str, vocab_size: usize) -> Tokenizer {
        let mut tok = Tokenizer::byte_level();
        let target = vocab_size.max(tok.vocab_size);
        let mut ids: Vec<u32> = corpus
            .bytes()
            .map(|b| BYTE_BASE + b as u32)
            .collect();
        while tok.vocab_size < target {
            let mut counts: BTreeMap<(u32, u32), usize> = BTreeMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &cnt)) =
                counts.iter().max_by_key(|(p, &c)| (c, std::cmp::Reverse(*p)))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let new_id = tok.vocab_size as u32;
            tok.merges.push(pair);
            tok.merge_map.insert(pair, new_id);
            tok.vocab_size += 1;
            // apply the merge
            let mut out = Vec::with_capacity(ids.len());
            let mut i = 0;
            while i < ids.len() {
                if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(ids[i]);
                    i += 1;
                }
            }
            ids = out;
        }
        tok
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> =
            text.bytes().map(|b| BYTE_BASE + b as u32).collect();
        // apply merges in training order (classic BPE inference)
        for (rank, &pair) in self.merges.iter().enumerate() {
            let new_id = (self.vocab_size - self.merges.len() + rank) as u32;
            if ids.len() < 2 {
                break;
            }
            let mut out = Vec::with_capacity(ids.len());
            let mut i = 0;
            while i < ids.len() {
                if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(ids[i]);
                    i += 1;
                }
            }
            ids = out;
        }
        ids
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            self.expand(id, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn expand(&self, id: u32, out: &mut Vec<u8>) {
        if id < N_SPECIAL {
            return; // specials render as nothing
        }
        if id < BYTE_BASE + 256 {
            out.push((id - BYTE_BASE) as u8);
            return;
        }
        let idx = (id as usize) - (BYTE_BASE as usize + 256);
        if let Some(&(l, r)) = self.merges.get(idx) {
            self.expand(l, out);
            self.expand(r, out);
        }
    }

    // -- serialization -----------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let v = json::obj(vec![
            ("vocab_size", json::num(self.vocab_size as f64)),
            (
                "merges",
                json::arr(
                    self.merges
                        .iter()
                        .map(|&(l, r)| {
                            json::arr(vec![
                                json::num(l as f64),
                                json::num(r as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(path, v.to_string())
            .with_context(|| format!("write {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Tokenizer> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let v = Value::parse(&text)
            .map_err(|e| anyhow::anyhow!("bad tokenizer json: {e}"))?;
        let mut tok = Tokenizer::byte_level();
        for m in v.req("merges")?.as_arr().context("merges not arr")? {
            let a = m.as_arr().context("merge not pair")?;
            let pair = (
                a[0].as_usize().unwrap() as u32,
                a[1].as_usize().unwrap() as u32,
            );
            let new_id = tok.vocab_size as u32;
            tok.merges.push(pair);
            tok.merge_map.insert(pair, new_id);
            tok.vocab_size += 1;
        }
        Ok(tok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let tok = Tokenizer::byte_level();
        let s = "hello, world! déjà";
        assert_eq!(tok.decode(&tok.encode(s)), s);
    }

    #[test]
    fn bpe_roundtrip_and_compresses() {
        let corpus = "the cat sat on the mat. the cat ran. the mat sat."
            .repeat(20);
        let tok = Tokenizer::train(&corpus, 300);
        assert!(tok.vocab_size > Tokenizer::byte_level().vocab_size);
        let s = "the cat sat on the mat.";
        let ids = tok.encode(s);
        assert_eq!(tok.decode(&ids), s);
        assert!(ids.len() < s.len(), "bpe should compress common text");
    }

    #[test]
    fn save_load_identical() {
        let corpus = "aa bb aa bb aa bb cc".repeat(30);
        let tok = Tokenizer::train(&corpus, 280);
        let dir = std::env::temp_dir().join("ao_tok_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tok.json");
        tok.save(&path).unwrap();
        let tok2 = Tokenizer::load(&path).unwrap();
        assert_eq!(tok2.merges, tok.merges);
        let s = "aa bb cc dd";
        assert_eq!(tok.encode(s), tok2.encode(s));
    }

    #[test]
    fn specials_decode_empty() {
        let tok = Tokenizer::byte_level();
        assert_eq!(tok.decode(&[PAD, BOS, EOS]), "");
    }
}
