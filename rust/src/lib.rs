//! # AO — training-to-serving model optimization, three-layer edition
//!
//! A reproduction of *TorchAO: PyTorch-Native Training-to-Serving Model
//! Optimization* (ICML 2025 CODEML) as a Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)**: serving engine (continuous batching, KV-cache
//!   slots, prefill/decode scheduling), training driver, checkpoint
//!   quantizer, eval harness, perf model, CLI — Python never runs on the
//!   request path.
//! - **L2 (python/compile)**: JAX transformer + quantize_ config API +
//!   FP8/QAT training recipes, AOT-lowered to `artifacts/*.hlo.txt`.
//! - **L1 (python/compile/kernels)**: Pallas quantization/sparsity kernels
//!   with pure-jnp oracles.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

// XLA backend selection. Everything PJRT goes through `crate::xb`: the
// real `xla` crate by default, or the vendored no-op shim when built with
// `--no-default-features --features stub-xla` (environments without
// libxla — the shim compiles and the host-only unit tests run; anything
// that actually executes an artifact returns a clear error).
#[cfg(all(feature = "xla", not(feature = "stub-xla")))]
pub use ::xla as xb;
#[cfg(all(feature = "stub-xla", not(feature = "xla")))]
pub use ::xla_stub as xb;
#[cfg(not(any(feature = "xla", feature = "stub-xla")))]
compile_error!(
    "enable either the `xla` backend feature (default) or `stub-xla`"
);
// Both at once would silently run 'tier-1' against the no-op shim on a
// real-backend machine — force the documented invocation instead:
// `--no-default-features --features stub-xla`.
#[cfg(all(feature = "xla", feature = "stub-xla"))]
compile_error!(
    "`stub-xla` requires --no-default-features (the real `xla` backend \
     and the stub are mutually exclusive)"
);

pub mod benchsupport;
pub mod ckpt;
pub mod coordinator;
pub mod data;
pub mod evalh;
pub mod modelcfg;
pub mod perfmodel;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod train;
pub mod util;

/// Repo-relative default artifact directory.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    util::env::var("AO_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// Default runs/output directory (loss curves, bench CSVs, checkpoints).
pub fn runs_dir() -> std::path::PathBuf {
    let dir = util::env::var("AO_RUNS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("runs")
        });
    let _ = std::fs::create_dir_all(&dir);
    dir
}
