//! Host-side tensors and Literal bridging.
//!
//! `HostTensor` is the repo's CPU tensor: a shape plus typed storage for
//! the four dtypes that cross the PJRT boundary (f32, s32, s8, u8). It is
//! deliberately minimal — XLA does the math; Rust only packs, routes, and
//! measures.

use crate::xb::{ElementType, Literal};
use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
    S8,
    U8,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "s32" | "i32" => DType::S32,
            "s8" | "i8" => DType::S8,
            "u8" => DType::U8,
            other => bail!("unsupported dtype '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::S32 => "s32",
            DType::S8 => "s8",
            DType::U8 => "u8",
        }
    }

    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::S32 => 4,
            DType::S8 | DType::U8 => 1,
        }
    }

    pub fn element_type(&self) -> ElementType {
        match self {
            DType::F32 => ElementType::F32,
            DType::S32 => ElementType::S32,
            DType::S8 => ElementType::S8,
            DType::U8 => ElementType::U8,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
    S8(Vec<i8>),
    U8(Vec<u8>),
}

impl Data {
    pub fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::S32(v) => v.len(),
            Data::S8(v) => v.len(),
            Data::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Data::F32(_) => DType::F32,
            Data::S32(_) => DType::S32,
            Data::S8(_) => DType::S8,
            Data::U8(_) => DType::U8,
        }
    }

    pub fn bytes(&self) -> &[u8] {
        // Safety: plain-old-data reinterpretation, alignment 1 <= source.
        unsafe {
            match self {
                Data::F32(v) => std::slice::from_raw_parts(
                    v.as_ptr() as *const u8, v.len() * 4,
                ),
                Data::S32(v) => std::slice::from_raw_parts(
                    v.as_ptr() as *const u8, v.len() * 4,
                ),
                Data::S8(v) => std::slice::from_raw_parts(
                    v.as_ptr() as *const u8, v.len(),
                ),
                Data::U8(v) => v.as_slice(),
            }
        }
    }

    pub fn from_bytes(dtype: DType, bytes: &[u8]) -> Result<Data> {
        Ok(match dtype {
            DType::F32 => {
                if bytes.len() % 4 != 0 {
                    bail!("byte length not a multiple of 4");
                }
                Data::F32(
                    bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            DType::S32 => Data::S32(
                bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            DType::S8 => Data::S8(bytes.iter().map(|&b| b as i8).collect()),
            DType::U8 => Data::U8(bytes.to_vec()),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Data) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} ({} elems) does not match data length {}",
                shape, n, data.len()
            );
        }
        Ok(HostTensor { shape, data })
    }

    pub fn f32(shape: Vec<usize>, v: Vec<f32>) -> HostTensor {
        HostTensor::new(shape, Data::F32(v)).unwrap()
    }

    pub fn s32(shape: Vec<usize>, v: Vec<i32>) -> HostTensor {
        HostTensor::new(shape, Data::S32(v)).unwrap()
    }

    pub fn s8(shape: Vec<usize>, v: Vec<i8>) -> HostTensor {
        HostTensor::new(shape, Data::S8(v)).unwrap()
    }

    pub fn u8(shape: Vec<usize>, v: Vec<u8>) -> HostTensor {
        HostTensor::new(shape, Data::U8(v)).unwrap()
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> HostTensor {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => Data::F32(vec![0.0; n]),
            DType::S32 => Data::S32(vec![0; n]),
            DType::S8 => Data::S8(vec![0; n]),
            DType::U8 => Data::U8(vec![0; n]),
        };
        HostTensor { shape, data }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_size(&self) -> usize {
        self.numel() * self.dtype().size()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is {:?}, not f32", self.dtype())),
        }
    }

    pub fn as_s32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::S32(v) => Ok(v),
            _ => Err(anyhow!("tensor is {:?}, not s32", self.dtype())),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            Data::U8(v) => Ok(v),
            _ => Err(anyhow!("tensor is {:?}, not u8", self.dtype())),
        }
    }

    pub fn as_s8(&self) -> Result<&[i8]> {
        match &self.data {
            Data::S8(v) => Ok(v),
            _ => Err(anyhow!("tensor is {:?}, not s8", self.dtype())),
        }
    }

    /// Host -> XLA literal (copies).
    pub fn to_literal(&self) -> Result<Literal> {
        Literal::create_from_shape_and_untyped_data(
            self.dtype().element_type(),
            &self.shape,
            self.data.bytes(),
        )
        .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }

    /// XLA literal -> host (copies).
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal has no array shape: {e:?}"))?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        let dtype = match shape.ty() {
            ElementType::F32 => DType::F32,
            ElementType::S32 => DType::S32,
            ElementType::S8 => DType::S8,
            ElementType::U8 => DType::U8,
            other => bail!("unsupported literal dtype {other:?}"),
        };
        let data = match dtype {
            DType::F32 => Data::F32(
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow!("to_vec f32: {e:?}"))?,
            ),
            DType::S32 => Data::S32(
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow!("to_vec i32: {e:?}"))?,
            ),
            DType::S8 => Data::S8(
                lit.to_vec::<i8>().map_err(|e| anyhow!("to_vec i8: {e:?}"))?,
            ),
            DType::U8 => Data::U8(
                lit.to_vec::<u8>().map_err(|e| anyhow!("to_vec u8: {e:?}"))?,
            ),
        };
        HostTensor::new(dims, data).context("literal shape mismatch")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_validation() {
        assert!(HostTensor::new(vec![2, 3], Data::F32(vec![0.0; 6])).is_ok());
        assert!(HostTensor::new(vec![2, 3], Data::F32(vec![0.0; 5])).is_err());
    }

    #[test]
    fn bytes_roundtrip_f32() {
        let t = HostTensor::f32(vec![3], vec![1.0, -2.5, 3.25]);
        let d = Data::from_bytes(DType::F32, t.data.bytes()).unwrap();
        assert_eq!(d, t.data);
    }

    #[test]
    fn bytes_roundtrip_s8() {
        let t = HostTensor::s8(vec![4], vec![-1, 2, -3, 127]);
        let d = Data::from_bytes(DType::S8, t.data.bytes()).unwrap();
        assert_eq!(d, t.data);
    }

    #[test]
    fn byte_size() {
        assert_eq!(HostTensor::zeros(DType::F32, vec![2, 2]).byte_size(), 16);
        assert_eq!(HostTensor::zeros(DType::U8, vec![2, 2]).byte_size(), 4);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("u8").unwrap(), DType::U8);
        assert!(DType::parse("f64").is_err());
    }
}
