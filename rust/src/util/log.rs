//! Leveled stderr logging with wall-clock offsets. Set `AO_LOG=debug` for
//! verbose output; default level is info. `AO_LOG=off` silences
//! everything — chaos tests use it so expected-fault noise doesn't drown
//! their own output.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

// 0=debug 1=info 2=warn 3=error 4=off (nothing passes `enabled`)
static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: OnceLock<Instant> = OnceLock::new();

/// The AO_LOG parse table. Unknown values (and unset) mean info.
pub fn level_from(s: &str) -> u8 {
    match s {
        "debug" => 0,
        "warn" => 2,
        "error" => 3,
        "off" => 4,
        _ => 1,
    }
}

pub fn init() {
    START.get_or_init(Instant::now);
    let lvl = crate::util::env::var("AO_LOG").unwrap_or_default();
    LEVEL.store(level_from(&lvl), Ordering::Relaxed);
}

pub fn enabled(level: u8) -> bool {
    level >= LEVEL.load(Ordering::Relaxed)
}

pub fn emit(level: u8, tag: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::emit(0, "dbg", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::emit(1, "inf", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log::emit(2, "wrn", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::emit(3, "err", &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_table() {
        assert_eq!(level_from("debug"), 0);
        assert_eq!(level_from("info"), 1);
        assert_eq!(level_from("warn"), 2);
        assert_eq!(level_from("error"), 3);
        assert_eq!(level_from("off"), 4);
        // unset / unknown both fall back to info
        assert_eq!(level_from(""), 1);
        assert_eq!(level_from("verbose"), 1);
    }

    #[test]
    fn off_silences_even_errors() {
        let prev = LEVEL.load(Ordering::Relaxed);
        LEVEL.store(level_from("off"), Ordering::Relaxed);
        assert!(!enabled(3));
        LEVEL.store(level_from("error"), Ordering::Relaxed);
        assert!(enabled(3));
        assert!(!enabled(2));
        LEVEL.store(prev, Ordering::Relaxed);
    }
}
