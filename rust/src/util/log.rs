//! Leveled stderr logging with wall-clock offsets. Set `AO_LOG=debug` for
//! verbose output; default level is info.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=debug 1=info 2=warn 3=error
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    let lvl = crate::util::env::var("AO_LOG").unwrap_or_default();
    LEVEL.store(
        match lvl.as_str() {
            "debug" => 0,
            "warn" => 2,
            "error" => 3,
            _ => 1,
        },
        Ordering::Relaxed,
    );
}

pub fn enabled(level: u8) -> bool {
    level >= LEVEL.load(Ordering::Relaxed)
}

pub fn emit(level: u8, tag: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::emit(0, "dbg", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::emit(1, "inf", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log::emit(2, "wrn", &format!($($arg)*)) };
}
