//! The ONE sanctioned process-environment read. `clippy.toml` disallows
//! bare `std::env::var` so every `AO_*` binding funnels through here;
//! that keeps the env contract greppable (ao-lint's config-surface rule
//! R3 checks each `EngineConfig` field has a string-literal `AO_*`
//! binding) and keeps unset-vs-non-unicode handling in one place.

/// Read an environment variable; `None` when unset or not unicode.
#[allow(clippy::disallowed_methods)]
pub fn var(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unset_reads_as_none() {
        assert_eq!(super::var("AO_TEST_SURELY_UNSET_VARIABLE"), None);
    }
}
