//! Tiny argument parser (clap is not in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.options
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(body.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} not a number")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} not a float")))
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || self.get(key).map(|v| v == "true" || v == "1").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = args(&["serve", "--port", "8080", "--verbose", "--x=1"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("x"), Some("1"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = args(&["--n", "5", "--lr", "0.5"]);
        assert_eq!(a.usize_or("n", 1), 5);
        assert_eq!(a.usize_or("m", 7), 7);
        assert!((a.f64_or("lr", 0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn flag_at_end() {
        let a = args(&["--force"]);
        assert!(a.flag("force"));
    }
}
