//! Measurement statistics for the bench harness (criterion is not in the
//! offline registry; `rust/benches/*` use this instead).

use std::time::{Duration, Instant};

/// Summary of a sample set (times in seconds or any unit).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

pub fn summarize(samples: &[f64]) -> Summary {
    let n = samples.len();
    if n == 0 {
        return Summary {
            n: 0, mean: f64::NAN, std: f64::NAN, min: f64::NAN,
            max: f64::NAN, p50: f64::NAN, p90: f64::NAN, p95: f64::NAN,
            p99: f64::NAN,
        };
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(1) as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 50.0),
        p90: percentile(&sorted, 90.0),
        p95: percentile(&sorted, 95.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Log-bucket streaming histogram: fixed memory regardless of sample
/// count, mergeable across collectors (fleet aggregation), with
/// percentile estimates bounded by the bucket geometry.
///
/// Bucket `i` covers `[HIST_MIN * HIST_GROWTH^i, HIST_MIN *
/// HIST_GROWTH^(i+1))` seconds; bucket 0 additionally absorbs everything
/// at or below `HIST_MIN` and the last bucket absorbs overflow. With
/// `HIST_MIN = 1µs` and 96 buckets of ×1.25 growth the range spans
/// ~1µs..2100s. Percentile estimates return the geometric midpoint of
/// the rank's bucket (clamped to the observed min/max), so the relative
/// error is at most `sqrt(HIST_GROWTH) − 1` ≈ 11.8% — strictly within
/// one bucket width of the exact-sample value.
pub const HIST_BUCKETS: usize = 96;
pub const HIST_MIN: f64 = 1e-6;
pub const HIST_GROWTH: f64 = 1.25;

/// Lower/upper bound of bucket `i` (seconds).
pub fn hist_bucket_bounds(i: usize) -> (f64, f64) {
    let lo = HIST_MIN * HIST_GROWTH.powi(i as i32);
    (lo, lo * HIST_GROWTH)
}

/// Bucket index for a sample (negatives/zeros land in bucket 0,
/// overflow in the last bucket; callers filter NaN).
pub fn hist_bucket_of(v: f64) -> usize {
    if !(v > HIST_MIN) {
        return 0;
    }
    let i = ((v / HIST_MIN).ln() / HIST_GROWTH.ln()).floor();
    if i < 0.0 {
        0
    } else {
        (i as usize).min(HIST_BUCKETS - 1)
    }
}

#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: [u64; HIST_BUCKETS],
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: [0; HIST_BUCKETS],
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.counts[hist_bucket_of(v)] += 1;
        self.n += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn len(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Fold another histogram in (fleet aggregation: per-worker
    /// histograms merge into one without resampling).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Non-empty buckets as `(index, count)` pairs — the wire format the
    /// JSON stats snapshot carries for external aggregators.
    pub fn sparse_counts(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect()
    }

    /// Nearest-rank percentile estimate: same rank formula as
    /// `percentile()`, resolved to the geometric midpoint of the bucket
    /// holding that rank, clamped to the observed min/max.
    pub fn percentile_est(&self, p: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        let rank = (p / 100.0 * (self.n - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if rank < seen {
                let (lo, hi) = hist_bucket_bounds(i);
                return (lo * hi).sqrt().clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Summary with exact n/mean/std/min/max (tracked as moments) and
    /// bucket-estimated percentiles.
    pub fn summary(&self) -> Summary {
        if self.n == 0 {
            return summarize(&[]);
        }
        let n = self.n as f64;
        let mean = self.sum / n;
        let var = (self.sum_sq / n - mean * mean).max(0.0);
        Summary {
            n: self.n as usize,
            mean,
            std: var.sqrt(),
            min: self.min,
            max: self.max,
            p50: self.percentile_est(50.0),
            p90: self.percentile_est(90.0),
            p95: self.percentile_est(95.0),
            p99: self.percentile_est(99.0),
        }
    }
}

/// Rolling-window histogram: a ring of per-window `LogHistogram`s over
/// a caller-supplied epoch clock (microseconds since some fixed origin —
/// the engine passes its trace-epoch time, tests pass synthetic values;
/// this type never reads a clock itself).
///
/// Time is divided into consecutive windows of `window_us`; window `w`
/// covers `[w*window_us, (w+1)*window_us)`. The ring keeps the most
/// recent `n_windows` of them: recording at a later timestamp advances
/// the ring, dropping any window that has fallen off the back. A rolling
/// percentile over the last `span_us` is the `merge` of every retained
/// window that *overlaps* `[now − span, now]` — so a span can include up
/// to one partially-expired window at the old edge, and the estimate
/// carries the same one-bucket error bound as `LogHistogram` itself.
#[derive(Debug, Clone)]
pub struct WindowedHistogram {
    window_us: u64,
    /// ring slot `w % n` holds window `w`'s histogram; `wins[i].0` is
    /// the window number the slot currently belongs to (`u64::MAX` =
    /// never written)
    wins: Vec<(u64, LogHistogram)>,
    /// highest window number ever advanced to (the "current" window)
    cur: u64,
}

/// Default SLO ring geometry: 32 windows of 10s each — a 320s horizon,
/// enough to answer both the 1-minute and 5-minute rolling queries.
pub const SLO_WINDOWS: usize = 32;
pub const SLO_WINDOW_US: u64 = 10_000_000;

impl Default for WindowedHistogram {
    fn default() -> Self {
        WindowedHistogram::new(SLO_WINDOWS, SLO_WINDOW_US)
    }
}

impl WindowedHistogram {
    /// `n_windows` ring slots of `window_us` microseconds each. Both are
    /// clamped to at least 1 so a misconfigured collector degrades to a
    /// tiny window instead of dividing by zero.
    pub fn new(n_windows: usize, window_us: u64) -> Self {
        WindowedHistogram {
            window_us: window_us.max(1),
            wins: vec![
                (u64::MAX, LogHistogram::new());
                n_windows.max(1)
            ],
            cur: 0,
        }
    }

    pub fn window_us(&self) -> u64 {
        self.window_us
    }

    pub fn n_windows(&self) -> usize {
        self.wins.len()
    }

    /// Roll the ring forward so `now_us` lands in the current window.
    /// Slots whose window number has been lapped are reset — this is
    /// where expired samples drop. Time never runs backwards here:
    /// a stale `now_us` records into the current window rather than
    /// resurrecting an expired one.
    pub fn advance(&mut self, now_us: u64) {
        let w = now_us / self.window_us;
        if w > self.cur {
            self.cur = w;
        }
    }

    /// Record one sample at epoch time `now_us`.
    pub fn record(&mut self, now_us: u64, v: f64) {
        self.advance(now_us);
        let n = self.wins.len();
        let slot = &mut self.wins[(self.cur % n as u64) as usize];
        if slot.0 != self.cur {
            *slot = (self.cur, LogHistogram::new());
        }
        slot.1.record(v);
    }

    /// Is window `w` still inside the ring's retention horizon? A slot
    /// whose window number has been lapped keeps its stale counts until
    /// the next record overwrites it, so every read path filters here.
    fn is_live(&self, w: u64, now_us: u64) -> bool {
        let horizon = (now_us / self.window_us).max(self.cur);
        w != u64::MAX
            && w <= horizon
            && w + self.wins.len() as u64 > horizon
    }

    /// Merge of every live window overlapping `[now − span, now]`.
    /// Windows that fell off the ring (or were never written) contribute
    /// nothing; an empty result means no samples landed in the span.
    pub fn merged_last(&self, now_us: u64, span_us: u64) -> LogHistogram {
        let mut out = LogHistogram::new();
        let cutoff = now_us.saturating_sub(span_us);
        for (w, h) in &self.wins {
            if !self.is_live(*w, now_us) || h.is_empty() {
                continue;
            }
            // overlap test: the window's end must be past the cutoff
            // and its start at or before now
            let (start, end) =
                (*w * self.window_us, (*w + 1) * self.window_us);
            if end > cutoff && start <= now_us {
                out.merge(h);
            }
        }
        out
    }

    /// Total samples currently retained across all live windows (as of
    /// epoch time `now_us`).
    pub fn len_at(&self, now_us: u64) -> u64 {
        self.wins
            .iter()
            .filter(|(w, _)| self.is_live(*w, now_us))
            .map(|(_, h)| h.len())
            .sum()
    }

    pub fn is_empty_at(&self, now_us: u64) -> bool {
        self.len_at(now_us) == 0
    }
}

/// Per-artifact execution profile entry: host-timed for now (the wall
/// clock around `execute_b`), named so device-event timing can replace
/// the source without changing consumers. Produced by the runtime,
/// rendered by the metrics report's `graphs[...]` table.
#[derive(Debug, Clone)]
pub struct GraphStat {
    /// artifact name (manifest key)
    pub name: String,
    /// executions observed
    pub calls: u64,
    /// cumulative execution wall time, microseconds
    pub exec_us: u64,
    /// per-call execution seconds, log-bucketed
    pub hist: LogHistogram,
}

/// Bench loop: warm up, then time `iters` calls, returning per-call seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Adaptive bench: run until `min_time` has elapsed or `max_iters` reached.
pub fn bench_for<F: FnMut()>(
    warmup: usize,
    min_time: Duration,
    max_iters: usize,
    mut f: F,
) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_time && out.len() < max_iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Current process peak RSS in bytes (Linux, /proc/self/status VmHWM).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Current RSS in bytes.
pub fn rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = summarize(&[]);
        assert!(s.mean.is_nan());
        assert!(s.p95.is_nan());
    }

    #[test]
    fn p95_between_p90_and_p99() {
        let v: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = summarize(&v);
        assert!(s.p90 <= s.p95 && s.p95 <= s.p99);
        assert_eq!(s.p95, 94.0);
    }

    #[test]
    fn rss_readable() {
        assert!(peak_rss_bytes().unwrap() > 0);
        assert!(rss_bytes().unwrap() > 0);
    }

    #[test]
    fn hist_bucket_boundaries() {
        // underflow and overflow clamp to the end buckets
        assert_eq!(hist_bucket_of(0.0), 0);
        assert_eq!(hist_bucket_of(-1.0), 0);
        assert_eq!(hist_bucket_of(HIST_MIN), 0);
        assert_eq!(hist_bucket_of(1e12), HIST_BUCKETS - 1);
        // consecutive buckets tile the range with ratio HIST_GROWTH
        for i in 0..HIST_BUCKETS - 1 {
            let (lo, hi) = hist_bucket_bounds(i);
            let (lo2, _) = hist_bucket_bounds(i + 1);
            assert!((hi / lo - HIST_GROWTH).abs() < 1e-12);
            assert!((lo2 - hi).abs() < hi * 1e-12);
        }
        // a recorded value falls inside its bucket's bounds
        for k in 1..400 {
            let v = 1e-5 * 1.09f64.powi(k);
            let i = hist_bucket_of(v);
            let (lo, hi) = hist_bucket_bounds(i);
            if i < HIST_BUCKETS - 1 {
                assert!(
                    v >= lo * (1.0 - 1e-9) && v <= hi * (1.0 + 1e-9),
                    "v={v} bucket={i} [{lo},{hi})"
                );
            }
        }
    }

    #[test]
    fn hist_merge_matches_combined() {
        let vals: Vec<f64> = (0..200)
            .map(|i| 1e-4 * (1.0 + ((i * 37) % 97) as f64))
            .collect();
        let mut all = LogHistogram::new();
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for (i, &v) in vals.iter().enumerate() {
            all.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.len(), all.len());
        assert_eq!(a.sparse_counts(), all.sparse_counts());
        let (sa, sall) = (a.summary(), all.summary());
        assert_eq!(sa.min, sall.min);
        assert_eq!(sa.max, sall.max);
        assert!((sa.mean - sall.mean).abs() < 1e-12);
        assert_eq!(sa.p95, sall.p95);
    }

    #[test]
    fn hist_percentiles_within_bucket_error_of_oracle() {
        // samples spanning several decades, deterministic shuffle
        let vals: Vec<f64> = (0..500)
            .map(|i| {
                let scale = 10f64.powi(-(((i * 13) % 4) as i32) - 1);
                scale * (1.0 + ((i * 2654435761u64 as usize) % 900) as f64 / 100.0)
            })
            .collect();
        let mut h = LogHistogram::new();
        for &v in &vals {
            h.record(v);
        }
        let exact = summarize(&vals);
        let est = h.summary();
        for (e, x) in [
            (est.p50, exact.p50),
            (est.p90, exact.p90),
            (est.p95, exact.p95),
            (est.p99, exact.p99),
        ] {
            let ratio = e / x;
            assert!(
                ratio >= 1.0 / HIST_GROWTH && ratio <= HIST_GROWTH,
                "estimate {e} vs exact {x}: off by more than one bucket"
            );
        }
        assert_eq!(est.min, exact.min);
        assert_eq!(est.max, exact.max);
        assert!((est.mean - exact.mean).abs() < 1e-12 * exact.mean.abs());
    }

    #[test]
    fn hist_empty_is_nan_like_summarize() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        let s = h.summary();
        assert!(s.mean.is_nan());
        assert!(s.p95.is_nan());
        assert!(h.percentile_est(50.0).is_nan());
    }

    #[test]
    fn windowed_full_horizon_merge_counts_live_samples() {
        // a full-horizon merge accounts for exactly the samples the ring
        // still retains — no double counting, no leakage from expired
        // slots
        let window_us = 10_000_000u64; // 10s
        let mut w = WindowedHistogram::new(32, window_us);
        let mut now = 0u64;
        for i in 0..500u64 {
            // 0.7s apart: ~350s of traffic, past the 320s horizon, so
            // the oldest windows expire along the way
            now = i * 700_000;
            let v = 1e-3 * (1.0 + (i % 97) as f64);
            w.record(now, v);
        }
        // span covering everything that is still live
        let span = window_us * 32;
        let merged = w.merged_last(now, span);
        let live: u64 = w.len_at(now);
        assert_eq!(merged.len(), live);
        // the most recent window is always live, so merges are non-empty
        assert!(!merged.is_empty());
    }

    #[test]
    fn windowed_short_run_merge_is_exact() {
        // a run shorter than the retention horizon loses nothing: merge
        // over the full span equals the flat histogram exactly
        let mut w = WindowedHistogram::new(32, 10_000_000);
        let mut flat = LogHistogram::new();
        let mut now = 0u64;
        for i in 0..300u64 {
            now = i * 500_000; // 150s total, horizon is 320s
            let v = 1e-4 * (1.0 + (i % 53) as f64);
            w.record(now, v);
            flat.record(v);
        }
        let merged = w.merged_last(now, u64::MAX);
        assert_eq!(merged.len(), flat.len());
        assert_eq!(merged.sparse_counts(), flat.sparse_counts());
        assert_eq!(merged.summary().p95, flat.summary().p95);
    }

    #[test]
    fn windowed_expired_windows_drop() {
        let window_us = 1_000_000u64;
        let n = 4usize;
        let mut w = WindowedHistogram::new(n, window_us);
        w.record(0, 1.0);
        assert_eq!(w.len_at(0), 1);
        // advance far past the retention horizon without recording: the
        // old sample must no longer be visible even though its ring slot
        // was never overwritten
        let later = window_us * (n as u64 + 3);
        assert_eq!(w.len_at(later), 0);
        assert!(w.merged_last(later, u64::MAX).is_empty());
        // and recording again reuses the slot cleanly
        w.record(later, 2.0);
        assert_eq!(w.len_at(later), 1);
        let m = w.merged_last(later, window_us);
        assert_eq!(m.len(), 1);
        assert_eq!(m.summary().max, 2.0);
    }

    #[test]
    fn windowed_span_excludes_old_windows() {
        let window_us = 1_000_000u64;
        let mut w = WindowedHistogram::new(8, window_us);
        w.record(0, 1.0); // window 0
        w.record(3 * window_us + 1, 2.0); // window 3
        // a one-window span at window 3 sees only the new sample
        let m = w.merged_last(3 * window_us + 1, window_us / 2);
        assert_eq!(m.len(), 1);
        assert_eq!(m.summary().min, 2.0);
        // a full-horizon span still sees both
        assert_eq!(w.merged_last(3 * window_us + 1, u64::MAX).len(), 2);
    }

    #[test]
    fn bench_counts() {
        let samples = bench(2, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(samples.len(), 5);
    }
}
