//! Measurement statistics for the bench harness (criterion is not in the
//! offline registry; `rust/benches/*` use this instead).

use std::time::{Duration, Instant};

/// Summary of a sample set (times in seconds or any unit).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

pub fn summarize(samples: &[f64]) -> Summary {
    let n = samples.len();
    if n == 0 {
        return Summary {
            n: 0, mean: f64::NAN, std: f64::NAN, min: f64::NAN,
            max: f64::NAN, p50: f64::NAN, p90: f64::NAN, p95: f64::NAN,
            p99: f64::NAN,
        };
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(1) as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 50.0),
        p90: percentile(&sorted, 90.0),
        p95: percentile(&sorted, 95.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Log-bucket streaming histogram: fixed memory regardless of sample
/// count, mergeable across collectors (fleet aggregation), with
/// percentile estimates bounded by the bucket geometry.
///
/// Bucket `i` covers `[HIST_MIN * HIST_GROWTH^i, HIST_MIN *
/// HIST_GROWTH^(i+1))` seconds; bucket 0 additionally absorbs everything
/// at or below `HIST_MIN` and the last bucket absorbs overflow. With
/// `HIST_MIN = 1µs` and 96 buckets of ×1.25 growth the range spans
/// ~1µs..2100s. Percentile estimates return the geometric midpoint of
/// the rank's bucket (clamped to the observed min/max), so the relative
/// error is at most `sqrt(HIST_GROWTH) − 1` ≈ 11.8% — strictly within
/// one bucket width of the exact-sample value.
pub const HIST_BUCKETS: usize = 96;
pub const HIST_MIN: f64 = 1e-6;
pub const HIST_GROWTH: f64 = 1.25;

/// Lower/upper bound of bucket `i` (seconds).
pub fn hist_bucket_bounds(i: usize) -> (f64, f64) {
    let lo = HIST_MIN * HIST_GROWTH.powi(i as i32);
    (lo, lo * HIST_GROWTH)
}

/// Bucket index for a sample (negatives/zeros land in bucket 0,
/// overflow in the last bucket; callers filter NaN).
pub fn hist_bucket_of(v: f64) -> usize {
    if !(v > HIST_MIN) {
        return 0;
    }
    let i = ((v / HIST_MIN).ln() / HIST_GROWTH.ln()).floor();
    if i < 0.0 {
        0
    } else {
        (i as usize).min(HIST_BUCKETS - 1)
    }
}

#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: [u64; HIST_BUCKETS],
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: [0; HIST_BUCKETS],
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.counts[hist_bucket_of(v)] += 1;
        self.n += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn len(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Fold another histogram in (fleet aggregation: per-worker
    /// histograms merge into one without resampling).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Non-empty buckets as `(index, count)` pairs — the wire format the
    /// JSON stats snapshot carries for external aggregators.
    pub fn sparse_counts(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect()
    }

    /// Nearest-rank percentile estimate: same rank formula as
    /// `percentile()`, resolved to the geometric midpoint of the bucket
    /// holding that rank, clamped to the observed min/max.
    pub fn percentile_est(&self, p: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        let rank = (p / 100.0 * (self.n - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if rank < seen {
                let (lo, hi) = hist_bucket_bounds(i);
                return (lo * hi).sqrt().clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Summary with exact n/mean/std/min/max (tracked as moments) and
    /// bucket-estimated percentiles.
    pub fn summary(&self) -> Summary {
        if self.n == 0 {
            return summarize(&[]);
        }
        let n = self.n as f64;
        let mean = self.sum / n;
        let var = (self.sum_sq / n - mean * mean).max(0.0);
        Summary {
            n: self.n as usize,
            mean,
            std: var.sqrt(),
            min: self.min,
            max: self.max,
            p50: self.percentile_est(50.0),
            p90: self.percentile_est(90.0),
            p95: self.percentile_est(95.0),
            p99: self.percentile_est(99.0),
        }
    }
}

/// Bench loop: warm up, then time `iters` calls, returning per-call seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Adaptive bench: run until `min_time` has elapsed or `max_iters` reached.
pub fn bench_for<F: FnMut()>(
    warmup: usize,
    min_time: Duration,
    max_iters: usize,
    mut f: F,
) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_time && out.len() < max_iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Current process peak RSS in bytes (Linux, /proc/self/status VmHWM).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Current RSS in bytes.
pub fn rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = summarize(&[]);
        assert!(s.mean.is_nan());
        assert!(s.p95.is_nan());
    }

    #[test]
    fn p95_between_p90_and_p99() {
        let v: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = summarize(&v);
        assert!(s.p90 <= s.p95 && s.p95 <= s.p99);
        assert_eq!(s.p95, 94.0);
    }

    #[test]
    fn rss_readable() {
        assert!(peak_rss_bytes().unwrap() > 0);
        assert!(rss_bytes().unwrap() > 0);
    }

    #[test]
    fn hist_bucket_boundaries() {
        // underflow and overflow clamp to the end buckets
        assert_eq!(hist_bucket_of(0.0), 0);
        assert_eq!(hist_bucket_of(-1.0), 0);
        assert_eq!(hist_bucket_of(HIST_MIN), 0);
        assert_eq!(hist_bucket_of(1e12), HIST_BUCKETS - 1);
        // consecutive buckets tile the range with ratio HIST_GROWTH
        for i in 0..HIST_BUCKETS - 1 {
            let (lo, hi) = hist_bucket_bounds(i);
            let (lo2, _) = hist_bucket_bounds(i + 1);
            assert!((hi / lo - HIST_GROWTH).abs() < 1e-12);
            assert!((lo2 - hi).abs() < hi * 1e-12);
        }
        // a recorded value falls inside its bucket's bounds
        for k in 1..400 {
            let v = 1e-5 * 1.09f64.powi(k);
            let i = hist_bucket_of(v);
            let (lo, hi) = hist_bucket_bounds(i);
            if i < HIST_BUCKETS - 1 {
                assert!(
                    v >= lo * (1.0 - 1e-9) && v <= hi * (1.0 + 1e-9),
                    "v={v} bucket={i} [{lo},{hi})"
                );
            }
        }
    }

    #[test]
    fn hist_merge_matches_combined() {
        let vals: Vec<f64> = (0..200)
            .map(|i| 1e-4 * (1.0 + ((i * 37) % 97) as f64))
            .collect();
        let mut all = LogHistogram::new();
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for (i, &v) in vals.iter().enumerate() {
            all.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.len(), all.len());
        assert_eq!(a.sparse_counts(), all.sparse_counts());
        let (sa, sall) = (a.summary(), all.summary());
        assert_eq!(sa.min, sall.min);
        assert_eq!(sa.max, sall.max);
        assert!((sa.mean - sall.mean).abs() < 1e-12);
        assert_eq!(sa.p95, sall.p95);
    }

    #[test]
    fn hist_percentiles_within_bucket_error_of_oracle() {
        // samples spanning several decades, deterministic shuffle
        let vals: Vec<f64> = (0..500)
            .map(|i| {
                let scale = 10f64.powi(-(((i * 13) % 4) as i32) - 1);
                scale * (1.0 + ((i * 2654435761u64 as usize) % 900) as f64 / 100.0)
            })
            .collect();
        let mut h = LogHistogram::new();
        for &v in &vals {
            h.record(v);
        }
        let exact = summarize(&vals);
        let est = h.summary();
        for (e, x) in [
            (est.p50, exact.p50),
            (est.p90, exact.p90),
            (est.p95, exact.p95),
            (est.p99, exact.p99),
        ] {
            let ratio = e / x;
            assert!(
                ratio >= 1.0 / HIST_GROWTH && ratio <= HIST_GROWTH,
                "estimate {e} vs exact {x}: off by more than one bucket"
            );
        }
        assert_eq!(est.min, exact.min);
        assert_eq!(est.max, exact.max);
        assert!((est.mean - exact.mean).abs() < 1e-12 * exact.mean.abs());
    }

    #[test]
    fn hist_empty_is_nan_like_summarize() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        let s = h.summary();
        assert!(s.mean.is_nan());
        assert!(s.p95.is_nan());
        assert!(h.percentile_est(50.0).is_nan());
    }

    #[test]
    fn bench_counts() {
        let samples = bench(2, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(samples.len(), 5);
    }
}
