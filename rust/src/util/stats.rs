//! Measurement statistics for the bench harness (criterion is not in the
//! offline registry; `rust/benches/*` use this instead).

use std::time::{Duration, Instant};

/// Summary of a sample set (times in seconds or any unit).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

pub fn summarize(samples: &[f64]) -> Summary {
    let n = samples.len();
    if n == 0 {
        return Summary {
            n: 0, mean: f64::NAN, std: f64::NAN, min: f64::NAN,
            max: f64::NAN, p50: f64::NAN, p90: f64::NAN, p95: f64::NAN,
            p99: f64::NAN,
        };
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(1) as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 50.0),
        p90: percentile(&sorted, 90.0),
        p95: percentile(&sorted, 95.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Bench loop: warm up, then time `iters` calls, returning per-call seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Adaptive bench: run until `min_time` has elapsed or `max_iters` reached.
pub fn bench_for<F: FnMut()>(
    warmup: usize,
    min_time: Duration,
    max_iters: usize,
    mut f: F,
) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_time && out.len() < max_iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Current process peak RSS in bytes (Linux, /proc/self/status VmHWM).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Current RSS in bytes.
pub fn rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = summarize(&[]);
        assert!(s.mean.is_nan());
        assert!(s.p95.is_nan());
    }

    #[test]
    fn p95_between_p90_and_p99() {
        let v: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = summarize(&v);
        assert!(s.p90 <= s.p95 && s.p95 <= s.p99);
        assert_eq!(s.p95, 94.0);
    }

    #[test]
    fn rss_readable() {
        assert!(peak_rss_bytes().unwrap() > 0);
        assert!(rss_bytes().unwrap() > 0);
    }

    #[test]
    fn bench_counts() {
        let samples = bench(2, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(samples.len(), 5);
    }
}
