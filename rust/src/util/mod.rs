//! From-scratch substrate utilities: JSON, PRNG, CLI, stats, logging,
//! property testing. The offline crate registry only carries `xla` and
//! `anyhow`, so everything else AO needs is implemented here.

pub mod cli;
pub mod env;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
