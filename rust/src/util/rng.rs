//! Deterministic PRNG (splitmix64 seeding + xoshiro256++) and the handful
//! of distributions the workload generators need. No `rand` crate in the
//! offline registry, so this is a from-scratch substrate (DESIGN.md §4).

/// xoshiro256++ — fast, high-quality, deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-request / per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (inverse-CDF via
    /// precomputed table would be faster; n is small here).
    pub fn zipf(&mut self, n: usize, s: f64, harmonic: f64) -> usize {
        let target = self.f64() * harmonic;
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            if acc >= target {
                return k;
            }
        }
        n - 1
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }
}

/// Hash-combine values into one well-mixed 64-bit seed (splitmix64
/// chain). Unlike a plain XOR — which collapses to 0 whenever two parts
/// are equal (the PR-2 seed bug: `seed ^ id` with `seed == id`) — every
/// part passes through a full avalanche round, and the combination is
/// order-sensitive, so `(a, b)` and `(b, a)` derive different streams.
pub fn mix_seed(parts: &[u64]) -> u64 {
    let mut state = 0u64;
    let mut out = 0u64;
    for &p in parts {
        state ^= p;
        out = out.rotate_left(23) ^ splitmix64(&mut state);
    }
    out
}

/// Precompute the generalized harmonic number used by `zipf`.
pub fn harmonic(n: usize, s: f64) -> f64 {
    (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn mix_seed_no_xor_collapse() {
        // regression: `seed ^ id` was 0 for every request where seed == id
        // (the server submits seed = id), collapsing all sampled requests
        // onto one RNG stream
        let mut seen = std::collections::BTreeSet::new();
        for id in 0..64u64 {
            assert!(
                seen.insert(mix_seed(&[id, id, 0])),
                "equal parts must still derive distinct seeds (id={id})"
            );
        }
    }

    #[test]
    fn mix_seed_is_deterministic_and_order_sensitive() {
        assert_eq!(mix_seed(&[1, 2, 3]), mix_seed(&[1, 2, 3]));
        assert_ne!(mix_seed(&[1, 2, 3]), mix_seed(&[3, 2, 1]));
        assert_ne!(mix_seed(&[1, 2, 3]), mix_seed(&[1, 2, 4]));
        assert_ne!(mix_seed(&[0, 0, 0]), mix_seed(&[0, 0, 1]));
    }

    #[test]
    fn mix_seed_streams_diverge() {
        // two requests with distinct ids but identical user seeds must
        // produce different sample streams
        let mut a = Rng::new(mix_seed(&[7, 1, 0]));
        let mut b = Rng::new(mix_seed(&[7, 2, 0]));
        assert!((0..8).any(|_| a.next_u64() != b.next_u64()));
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(13);
        let h = harmonic(100, 1.1);
        let mut first = 0;
        for _ in 0..1000 {
            if r.zipf(100, 1.1, h) == 0 {
                first += 1;
            }
        }
        assert!(first > 100); // rank 0 is heavily favored
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
