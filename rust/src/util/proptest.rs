//! Mini property-testing harness (proptest is not in the offline registry).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` random inputs from
//! `gen`; on failure it performs greedy shrinking via the input's `Shrink`
//! implementation and panics with the minimal counterexample.

use super::rng::Rng;
use std::fmt::Debug;

pub trait Shrink: Sized {
    /// Candidate "smaller" versions of self, in decreasing aggressiveness.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            return vec![];
        }
        vec![0.0, self / 2.0, self.trunc()]
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        out.push(self[..self.len() - 1].to_vec());
        // element-wise shrink of the first element
        if let Some(first_shrunk) = self[0].shrink().into_iter().next() {
            let mut v = self.clone();
            v[0] = first_shrunk;
            out.push(v);
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over random cases with shrinking.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Clone + Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let seed = crate::util::env::var("AO_PROPTEST_SEED")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA0_5EED);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // shrink
            let mut best = (input, msg);
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in best.0.shrink() {
                    if let Err(m2) = prop(&cand) {
                        best = (cand, m2);
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  \
                 input: {:?}\n  error: {}",
                best.0, best.1
            );
        }
    }
}

/// Generators.
pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.normal() as f32) * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |r| (r.below(100), r.below(100)),
              |&(a, b)| {
                  if a + b == b + a { Ok(()) } else { Err("!".into()) }
              });
    }

    #[test]
    #[should_panic(expected = "shrunk-to-zero")]
    fn failing_property_shrinks() {
        check("always-fails", 10, |r| r.below(1000) + 1, |&n| {
            if n == 0 {
                Ok(())
            } else if n <= 1 {
                Err("shrunk-to-zero".into())
            } else {
                Err("big".into())
            }
        });
    }
}
