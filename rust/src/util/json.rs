//! Minimal-dependency JSON parser + writer.
//!
//! The offline crate registry has no serde, so AO carries its own JSON
//! implementation: a recursive-descent parser into a `Value` enum and a
//! compact writer. Covers the full JSON grammar (strings with escapes and
//! \uXXXX, exponent floats, nested containers); numbers are stored as f64
//! which is lossless for every integer the manifest/checkpoints use
//! (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Default)]
pub enum Value {
    #[default]
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Value {
    pub fn parse(s: &str) -> Result<Value, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `v.get("a")` on an object; None otherwise.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers that produce readable errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building JSON documents.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or_else(|| self.err("eof in escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // NOTE: surrogate pairs outside the BMP are not
                            // produced by any writer in this repo.
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                _ => {
                    // consume one utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"num":-3,"obj":{"k":true}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        let v = Value::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
    }

    #[test]
    fn escapes_on_write() {
        let v = Value::Str("a\"b\\c\nd".into());
        assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
    }
}
