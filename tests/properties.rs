//! Property-based tests over the Rust substrates (mini-proptest harness,
//! `ao::util::proptest`): invariants that must hold for arbitrary inputs.

use ao::coordinator::kvslots::{Slot, SlotPhase, SlotTable};
use ao::coordinator::pager::Pager;
use ao::coordinator::scheduler::{
    chunk_len, effective_budget, pick_preemption_victim, StepBudget,
};
use ao::quant::apply::{
    quant_int4_group_asym, quant_int4_group_sym, quant_int8_channelwise,
    quant_fp8_rowwise, sparse24_compress,
};
use ao::quant::formats::{
    pack_int4, unpack_int4_signed, unpack_int4_unsigned, E4M3,
    ALL_FORMATS,
};
use ao::quant::kvcache::{dequantize_groups, quantize_groups};
use ao::tokenizer::Tokenizer;
use ao::util::json::Value;
use ao::util::proptest::{check, vec_f32};
use ao::util::rng::Rng;
use ao::util::stats::{percentile, summarize};

#[test]
fn prop_int8_quant_error_bounded() {
    check(
        "int8-quant-error",
        40,
        |r| {
            let n = 1 + r.below(8);
            let k = 8 * (1 + r.below(8));
            (vec![n, k], vec_f32(r, n * k, 3.0))
        },
        |(shape, w)| {
            let (n, k) = (shape[0], shape[1]);
            let (q, s) = quant_int8_channelwise(w, n, k);
            for i in 0..n {
                for j in 0..k {
                    let d = q[i * k + j] as f32 * s[i];
                    let err = (d - w[i * k + j]).abs();
                    if err > s[i] * 0.5 + 1e-5 {
                        return Err(format!(
                            "err {err} > half-scale {} at ({i},{j})", s[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_int8_roundtrip_error_bounded() {
    // per-head int8 KV reconstruction (the serving cache's int8 scheme):
    // every element round-trips within half a quantization step of its
    // head group's absmax scale, values stay in [-127, 127], and the
    // group absmax element is reconstructed to its own magnitude
    check(
        "kv-int8-roundtrip",
        40,
        |r| {
            let d = [8usize, 16, 32][r.below(3)]; // head_dim
            let rows = 1 + r.below(6); // (layer, slot, head, pos) groups
            (vec![rows, d], vec_f32(r, rows * d, 2.5))
        },
        |(shape, x)| {
            let d = shape[1];
            let (q, s) = quantize_groups(x, d);
            if q.iter().any(|&v| !(-127..=127).contains(&(v as i32))) {
                return Err("int8 value out of range".into());
            }
            let rec = dequantize_groups(&q, &s, d);
            for (i, (&orig, &r2)) in x.iter().zip(&rec).enumerate() {
                let bound = s[i / d] * 0.5 + 1e-7;
                if (orig - r2).abs() > bound {
                    return Err(format!(
                        "elem {i}: |{orig} - {r2}| > half-scale {bound}"
                    ));
                }
            }
            for (g, chunk) in x.chunks_exact(d).enumerate() {
                let amax =
                    chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                if amax > 1e-6 {
                    let expect = amax / 127.0;
                    if (s[g] - expect).abs() > expect * 1e-5 {
                        return Err(format!(
                            "group {g}: scale {} != absmax/127 {expect}",
                            s[g]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_int4_asym_dequant_in_range() {
    check(
        "int4-asym-range",
        30,
        |r| {
            let n = 1 + r.below(6);
            let g = [16usize, 32][r.below(2)];
            let k = g * (1 + r.below(4));
            (vec![n, k, g], vec_f32(r, n * k, 2.0))
        },
        |(meta, w)| {
            let (n, k, g) = (meta[0], meta[1], meta[2]);
            let (p, s, zp) = quant_int4_group_asym(w, n, k, g);
            let un = unpack_int4_unsigned(&p);
            let ng = k / g;
            for i in 0..n {
                for j in 0..k {
                    let gi = j / g;
                    let (sc, z) = (s[i * ng + gi], zp[i * ng + gi]);
                    let d = (un[i * k + j] as f32 - z) * sc;
                    // dequantized value stays within the group's [min,max]
                    // extended by one quantum
                    let grp = &w[i * k + gi * g..i * k + (gi + 1) * g];
                    let mn = grp.iter().cloned().fold(f32::INFINITY, f32::min);
                    let mx =
                        grp.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    if d < mn.min(0.0) - sc || d > mx.max(0.0) + sc {
                        return Err(format!("dequant {d} outside [{mn},{mx}]"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_int4_pack_roundtrip() {
    check(
        "int4-pack-roundtrip",
        50,
        |r| {
            let len = 2 * (1 + r.below(64));
            (0..len)
                .map(|_| (r.below(16) as i8) - 8)
                .collect::<Vec<i8>>()
                .iter()
                .map(|&v| v as f32)
                .collect::<Vec<f32>>()
        },
        |vals| {
            let as_i8: Vec<i8> = vals.iter().map(|&v| v as i8).collect();
            let rt = unpack_int4_signed(&pack_int4(&as_i8));
            if rt == as_i8 {
                Ok(())
            } else {
                Err(format!("{as_i8:?} != {rt:?}"))
            }
        },
    );
}

#[test]
fn prop_fp8_cast_idempotent_and_bounded() {
    check(
        "fp8-cast",
        60,
        |r| vec_f32(r, 32, 50.0),
        |xs| {
            for fmt in ALL_FORMATS {
                for &x in xs {
                    let c = fmt.cast(x);
                    if fmt.cast(c) != c {
                        return Err(format!("{}: cast not idempotent at {x}", fmt.name));
                    }
                    if c.abs() > fmt.max_val {
                        return Err(format!("{}: |{c}| > max", fmt.name));
                    }
                    // relative error bound for values in range (normals)
                    let xa = x.abs();
                    if xa >= fmt.min_normal() && xa <= fmt.max_val {
                        let rel = (c - x).abs() / xa;
                        let bound = 0.5 / (1 << fmt.mbits) as f32 * 1.01;
                        if rel > bound {
                            return Err(format!(
                                "{}: rel err {rel} > {bound} at {x}", fmt.name
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fp8_rowwise_decode_recovers() {
    check(
        "fp8-rowwise-roundtrip",
        30,
        |r| {
            let n = 1 + r.below(6);
            let k = 8 * (1 + r.below(6));
            (vec![n, k], vec_f32(r, n * k, 4.0))
        },
        |(shape, w)| {
            let (n, k) = (shape[0], shape[1]);
            let (codes, scales) = quant_fp8_rowwise(w, n, k);
            for i in 0..n {
                for j in 0..k {
                    let d = E4M3.decode(codes[i * k + j]) / scales[i];
                    let orig = w[i * k + j];
                    if (d - orig).abs() > orig.abs() * 0.07 + 1e-4 {
                        return Err(format!("({i},{j}): {d} vs {orig}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sparse24_exactly_two_per_group() {
    check(
        "sparse24-2of4",
        30,
        |r| {
            let n = 1 + r.below(6);
            let k = 4 * (1 + r.below(16));
            (vec![n, k], vec_f32(r, n * k, 1.0))
        },
        |(shape, w)| {
            let (n, k) = (shape[0], shape[1]);
            let (vals, idx) = sparse24_compress(w, n, k);
            for i in 0..n {
                for gi in 0..k / 4 {
                    let a = idx[i * k / 2 + gi * 2] as usize;
                    let b = idx[i * k / 2 + gi * 2 + 1] as usize;
                    if a >= 4 || b >= 4 || a >= b {
                        return Err(format!("bad idx pair ({a},{b})"));
                    }
                    // kept values carry their original entries
                    let grp = &w[i * k + gi * 4..i * k + gi * 4 + 4];
                    if vals[i * k / 2 + gi * 2] != grp[a]
                        || vals[i * k / 2 + gi * 2 + 1] != grp[b]
                    {
                        return Err("values don't match positions".into());
                    }
                    // kept magnitude >= every dropped magnitude
                    let kept_min = grp[a].abs().min(grp[b].abs());
                    for (p, &v) in grp.iter().enumerate() {
                        if p != a && p != b && v.abs() > kept_min + 1e-7 {
                            return Err(format!(
                                "dropped {v} larger than kept {kept_min}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_8da4w_group_scale_quantizes_within_range() {
    check(
        "8da4w-sym-range",
        30,
        |r| {
            let n = 1 + r.below(4);
            let k = 32 * (1 + r.below(4));
            (vec![n, k], vec_f32(r, n * k, 2.0))
        },
        |(shape, w)| {
            let (n, k) = (shape[0], shape[1]);
            let (p, _s) = quant_int4_group_sym(w, n, k, 32);
            for v in unpack_int4_signed(&p) {
                if !(-8..=7).contains(&v) {
                    return Err(format!("int4 value {v} out of range"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn gen_value(r: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(r.chance(0.5)),
            2 => Value::Num((r.normal() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let len = r.below(12);
                Value::Str(
                    (0..len)
                        .map(|_| {
                            let opts = ['a', 'é', '"', '\\', '\n', '7', ' '];
                            opts[r.below(opts.len())]
                        })
                        .collect(),
                )
            }
            4 => Value::Arr(
                (0..r.below(4)).map(|_| gen_value(r, depth - 1)).collect(),
            ),
            _ => Value::Obj(
                (0..r.below(4))
                    .map(|i| (format!("k{i}"), gen_value(r, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(0x150);
    for _ in 0..200 {
        let v = gen_value(&mut rng, 3);
        let rt = Value::parse(&v.to_string()).expect("reparse");
        assert_eq!(rt, v);
    }
}

#[test]
fn prop_tokenizer_roundtrip_ascii() {
    let corpus = "the cat sat on the mat and the dog ran far ".repeat(30);
    let tok = Tokenizer::train(&corpus, 300);
    check(
        "bpe-roundtrip",
        60,
        |r| {
            let len = r.below(40);
            (0..len)
                .map(|_| (32 + r.below(95)) as u8 as char as u32 as f32)
                .collect::<Vec<f32>>()
        },
        |chars| {
            let s: String =
                chars.iter().map(|&c| (c as u8) as char).collect();
            let rt = tok.decode(&tok.encode(&s));
            if rt == s {
                Ok(())
            } else {
                Err(format!("{s:?} -> {rt:?}"))
            }
        },
    );
}

#[test]
fn prop_slot_table_never_double_allocates() {
    let mut rng = Rng::new(0x51_07);
    for _ in 0..50 {
        let b = 1 + rng.below(8);
        let mut table = SlotTable::new(b, 64);
        let mut live: Vec<usize> = Vec::new();
        for op in 0..200 {
            if rng.chance(0.55) {
                if let Some(idx) = table.claim(Slot {
                    request_id: op as u64,
                    pos: 1,
                    n_prompt: 1,
                    n_generated: 0,
                    max_new_tokens: 4,
                    temperature: 0.0,
                    rng_state: 0,
                    phase: SlotPhase::Decoding,
                }) {
                    assert!(
                        !live.contains(&idx),
                        "slot {idx} double-allocated"
                    );
                    live.push(idx);
                }
            } else if !live.is_empty() {
                let pick = rng.below(live.len());
                let idx = live.swap_remove(pick);
                assert!(table.release(idx).is_some());
            }
            assert_eq!(table.n_active(), live.len());
            assert!(table.n_active() <= b);
        }
    }
}

#[test]
fn prop_pager_invariants() {
    // The paged-KV allocator under random admit/grow/release traffic:
    //   - a page is never owned by two slots at once
    //   - occupancy == the sum of per-slot block-table lengths
    //   - freed pages return to the pool (drained pager == fresh pager)
    //   - the high-water mark is monotone and bounds current usage
    //   - reservations make growth infallible up to the reserved length
    let mut rng = Rng::new(0x9A_6E);
    for case in 0..30 {
        let page_size = [4usize, 8][rng.below(2)];
        let blocks_per_slot = 1 + rng.below(4);
        let smax = page_size * blocks_per_slot;
        let batch = 1 + rng.below(4);
        // pools from starved to over-provisioned
        let n_pages = 1 + rng.below(batch * blocks_per_slot + 2);
        let mut p = Pager::new(n_pages, page_size, batch, blocks_per_slot);
        let mut live: Vec<Option<usize>> = vec![None; batch]; // reserve_len
        let mut last_hwm = 0usize;
        for op in 0..200 {
            match rng.below(3) {
                0 => {
                    if let Some(slot) =
                        (0..batch).find(|&s| live[s].is_none())
                    {
                        let prompt = 1 + rng.below(smax);
                        let reserve =
                            (prompt + rng.below(smax)).min(smax);
                        if p.can_admit(reserve) {
                            p.admit(slot, prompt, reserve).unwrap();
                            live[slot] = Some(reserve);
                        } else {
                            assert!(
                                p.admit(slot, prompt, reserve).is_err(),
                                "admit past can_admit must fail \
                                 (case {case} op {op})"
                            );
                        }
                    }
                }
                1 => {
                    let live_slots: Vec<usize> = (0..batch)
                        .filter(|&s| live[s].is_some())
                        .collect();
                    if !live_slots.is_empty() {
                        let slot = live_slots[rng.below(live_slots.len())];
                        let reserve = live[slot].unwrap();
                        // any position inside the reservation must grow
                        // without ever exhausting the pool
                        let pos = rng.below(reserve);
                        p.grow(slot, pos).unwrap();
                    }
                }
                _ => {
                    let live_slots: Vec<usize> = (0..batch)
                        .filter(|&s| live[s].is_some())
                        .collect();
                    if !live_slots.is_empty() {
                        let slot = live_slots[rng.below(live_slots.len())];
                        p.release(slot);
                        live[slot] = None;
                    }
                }
            }
            // exclusive ownership + occupancy accounting
            let mut seen = std::collections::BTreeSet::new();
            let mut total_blocks = 0usize;
            for s in 0..batch {
                let table = p.block_table(s);
                if live[s].is_none() {
                    assert!(table.is_empty(), "idle slot owns pages");
                }
                for &page in table {
                    assert!((page as usize) < n_pages, "page id in range");
                    assert!(
                        seen.insert(page),
                        "page {page} owned by two slots (case {case})"
                    );
                }
                total_blocks += table.len();
            }
            assert_eq!(p.used_pages(), total_blocks);
            assert_eq!(p.used_pages() + p.free_pages(), n_pages);
            assert!(p.hwm() >= p.used_pages());
            assert!(p.hwm() >= last_hwm, "hwm must be monotone");
            last_hwm = p.hwm();
        }
        // drain: every page returns to the pool
        for s in 0..batch {
            p.release(s);
        }
        assert_eq!(p.used_pages(), 0);
        assert_eq!(p.free_pages(), n_pages);
    }
}

#[test]
fn prop_pager_shared_invariants() {
    // The prefix-sharing pager under random interleaved
    // admit/admit_shared/publish/grow/release traffic:
    //   - refcount == number of block tables referencing each page
    //     (1 for private pages, 0 for free/cached)
    //   - occupancy == sum of table lengths minus the sharing overlap
    //   - used + free + cached partitions the pool
    //   - reserved growth stays infallible with shared prefixes mapped
    //   - draining every slot and evicting the cached LRU restores a
    //     fresh pool
    let mut rng = Rng::new(0x5A4E_D0);
    for case in 0..25 {
        let page_size = [4usize, 8][rng.below(2)];
        let blocks_per_slot = 2 + rng.below(3);
        let smax = page_size * blocks_per_slot;
        let batch = 2 + rng.below(3);
        let n_pages =
            blocks_per_slot + 1 + rng.below(batch * blocks_per_slot);
        let mut p = Pager::new(n_pages, page_size, batch, blocks_per_slot);
        let mut live: Vec<Option<usize>> = vec![None; batch]; // reserve_len
        // published page chains (prefix order) sharing may draw from;
        // the real engine's index also checks content — here only the
        // pager's structural invariants are under test
        let mut published: Vec<Vec<u32>> = Vec::new();
        for op in 0..250 {
            match rng.below(4) {
                0 => {
                    let Some(slot) =
                        (0..batch).find(|&s| live[s].is_none())
                    else {
                        continue;
                    };
                    let prompt = 1 + rng.below(smax);
                    let reserve = (prompt + rng.below(smax)).min(smax);
                    // candidate shared prefix: a published chain trimmed
                    // to still-shareable pages, capped one block below
                    // the prompt's coverage (full-page-only sharing)
                    let mut shared: Vec<u32> = Vec::new();
                    if !published.is_empty() && rng.chance(0.7) {
                        let chain = &published[rng.below(published.len())];
                        let cap = (prompt - 1) / page_size;
                        for &pg in chain.iter().take(cap) {
                            if p.page_is_shareable(pg) {
                                shared.push(pg);
                            } else {
                                break;
                            }
                        }
                    }
                    if p.can_admit_shared(reserve, &shared) {
                        p.admit_shared(slot, &shared, prompt, reserve)
                            .unwrap();
                        live[slot] = Some(reserve);
                        let full = prompt / page_size;
                        p.publish_prefix(slot, full).unwrap();
                        if full > 0 {
                            published
                                .push(p.block_table(slot)[..full].to_vec());
                        }
                    } else {
                        assert!(
                            p.admit_shared(slot, &shared, prompt, reserve)
                                .is_err(),
                            "admit past can_admit_shared must fail \
                             (case {case} op {op})"
                        );
                    }
                }
                1 => {
                    let slots: Vec<usize> =
                        (0..batch).filter(|&s| live[s].is_some()).collect();
                    if let Some(&slot) =
                        slots.get(rng.below(slots.len().max(1)))
                    {
                        let reserve = live[slot].unwrap();
                        // growth inside the reservation must never fail,
                        // shared prefix mapped or not
                        p.grow(slot, rng.below(reserve)).unwrap();
                    }
                }
                2 => {
                    let slots: Vec<usize> =
                        (0..batch).filter(|&s| live[s].is_some()).collect();
                    if let Some(&slot) =
                        slots.get(rng.below(slots.len().max(1)))
                    {
                        p.release(slot);
                        live[slot] = None;
                    }
                }
                _ => {
                    // the engine drains evictions every admission/step
                    p.take_evicted();
                }
            }
            // refcount == number of referencing block tables, per page
            let mut refs = vec![0u32; n_pages];
            let mut total_blocks = 0usize;
            for s in 0..batch {
                if live[s].is_none() {
                    assert!(p.block_table(s).is_empty());
                }
                for &pg in p.block_table(s) {
                    assert!((pg as usize) < n_pages);
                    refs[pg as usize] += 1;
                }
                total_blocks += p.block_table(s).len();
            }
            for pg in 0..n_pages as u32 {
                assert_eq!(
                    p.refs(pg),
                    refs[pg as usize],
                    "page {pg} refcount != referencing tables (case {case})"
                );
            }
            // occupancy: distinct pages across tables, i.e. the sum of
            // table lengths minus the sharing overlap
            let distinct =
                refs.iter().filter(|&&c| c > 0).count();
            let overlap: usize = refs
                .iter()
                .map(|&c| (c as usize).saturating_sub(1))
                .sum();
            assert_eq!(p.used_pages(), distinct);
            assert_eq!(p.used_pages(), total_blocks - overlap);
            assert_eq!(
                p.used_pages() + p.free_pages() + p.cached_pages(),
                n_pages,
                "states must partition the pool (case {case})"
            );
            assert!(p.hwm() >= p.used_pages());
        }
        // drain: every slot released, cached LRU evicted -> fresh pool
        for s in 0..batch {
            p.release(s);
        }
        assert_eq!(p.used_pages(), 0);
        p.evict_all_cached();
        assert_eq!(p.free_pages(), n_pages);
        assert_eq!(p.cached_pages(), 0);
    }
}

#[test]
fn prop_percentiles_ordered() {
    check(
        "percentile-order",
        50,
        |r| {
            let len = 1 + r.below(100);
            vec_f32(r, len, 10.0)
        },
        |xs| {
            let v: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
            let s = summarize(&v);
            let mut sorted = v.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let checks = [
                (s.min <= s.p50, "min<=p50"),
                (s.p50 <= s.p90, "p50<=p90"),
                (s.p90 <= s.p95, "p90<=p95"),
                (s.p95 <= s.p99, "p95<=p99"),
                (s.p99 <= s.max, "p99<=max"),
                (
                    percentile(&sorted, 0.0) == s.min,
                    "p0==min",
                ),
            ];
            for (ok, name) in checks {
                if !ok {
                    return Err(name.into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scheduler_invariants() {
    // The iteration-level scheduling policy under randomized mixed
    // workloads (simulated over the pure `scheduler` functions, the same
    // ones the engine calls):
    //   - the per-step token total (decode rows + prefill chunks) never
    //     exceeds the effective budget
    //   - decode rows are never displaced: every decoding request emits
    //     exactly one token per step, however heavy the prefill pressure
    //   - FCFS within class: requests START prefill in arrival order
    //   - the preemption victim is always the youngest decoding slot,
    //     and a preempted request still runs to completion
    struct Running {
        id: usize,
        remaining_prefill: usize,
        left_decode: usize,
        emitted: usize,
        admit_seq: u64,
        resumed: bool,
    }
    let mut rng = Rng::new(0x5C_4E_D0);
    for case in 0..40 {
        let batch = 2 + rng.below(6);
        let chunk_cap = [8usize, 16, 32][rng.below(3)];
        let budget = effective_budget(1 + rng.below(48), batch, 1);
        let n_req = 3 + rng.below(10);
        // arrival order == id order; (prompt_len, max_new)
        let mut queue: Vec<(usize, usize, usize)> = (0..n_req)
            .map(|id| (id, 1 + rng.below(60), 1 + rng.below(6)))
            .collect();
        let mut running: Vec<Running> = Vec::new();
        let mut next_seq = 0u64;
        let mut first_starts: Vec<usize> = Vec::new();
        let mut finished: Vec<usize> = Vec::new();
        let mut n_preempted = 0usize;
        let mut steps = 0usize;
        while !queue.is_empty() || !running.is_empty() {
            steps += 1;
            assert!(steps < 10_000, "scheduler wedged (case {case})");
            let decode_rows = running
                .iter()
                .filter(|r| r.remaining_prefill == 0)
                .count();
            let mut b = StepBudget::open(budget, decode_rows);
            // continuation chunks, oldest admission first
            for r in running.iter_mut() {
                if r.remaining_prefill == 0 {
                    continue;
                }
                let c = chunk_len(r.remaining_prefill, chunk_cap, b.left());
                if c == 0 {
                    break;
                }
                b.charge(c);
                r.remaining_prefill -= c;
            }
            // admissions fill leftover budget, FCFS
            while b.left() > 0 && running.len() < batch && !queue.is_empty()
            {
                let (id, n_prompt, max_new) = queue.remove(0);
                let seq = next_seq;
                next_seq += 1;
                let mut r = Running {
                    id,
                    remaining_prefill: n_prompt,
                    left_decode: max_new,
                    emitted: 0,
                    admit_seq: seq,
                    resumed: first_starts.contains(&id),
                };
                if !r.resumed {
                    first_starts.push(id);
                }
                let c = chunk_len(r.remaining_prefill, chunk_cap, b.left());
                b.charge(c);
                r.remaining_prefill -= c;
                running.push(r);
            }
            assert!(
                b.spent <= b.budget,
                "step total {} exceeds budget {} (case {case})",
                b.spent,
                b.budget
            );
            // decode: every prefill-complete request advances by exactly
            // one token this step — never displaced by prefill work
            let mut advanced = 0usize;
            for r in running.iter_mut() {
                if r.remaining_prefill == 0 && r.left_decode > 0 {
                    r.left_decode -= 1;
                    r.emitted += 1;
                    advanced += 1;
                }
            }
            assert_eq!(
                advanced, decode_rows,
                "a decode row was displaced (case {case})"
            );
            // page-pressure preemption: youngest decoding slot, fresh
            // admissions only (resume heads never preempt -> no livelock)
            if !queue.is_empty()
                && running.len() == batch
                && rng.chance(0.25)
            {
                let candidates: Vec<(usize, u64)> = running
                    .iter()
                    .enumerate()
                    .filter(|(_, r)| {
                        r.remaining_prefill == 0
                            && r.left_decode > 0
                            && !r.resumed
                    })
                    .map(|(i, r)| (i, r.admit_seq))
                    .collect();
                if let Some(vi) = pick_preemption_victim(candidates.clone())
                {
                    let max_seq =
                        candidates.iter().map(|&(_, s)| s).max().unwrap();
                    assert_eq!(
                        running[vi].admit_seq, max_seq,
                        "victim must be the youngest (case {case})"
                    );
                    let v = running.swap_remove(vi);
                    n_preempted += 1;
                    // the resumed prompt embeds the emitted tokens; the
                    // last sampled token rides along as pending, so no
                    // decode progress is lost
                    queue.insert(
                        0,
                        (v.id, v.remaining_prefill + v.emitted, v.left_decode),
                    );
                }
            }
            running.retain(|r| {
                if r.remaining_prefill == 0 && r.left_decode == 0 {
                    finished.push(r.id);
                    false
                } else {
                    true
                }
            });
        }
        // everyone completes, preempted or not
        finished.sort_unstable();
        assert_eq!(
            finished,
            (0..n_req).collect::<Vec<_>>(),
            "{n_preempted} preemptions, case {case}"
        );
        // FCFS within class: first prefill starts follow arrival order
        let mut sorted = first_starts.clone();
        sorted.sort_unstable();
        assert_eq!(
            first_starts, sorted,
            "prefill must start in arrival order (case {case})"
        );
    }
}

#[test]
fn prop_request_lifecycle() {
    // The request lifecycle under random admission, decode progress,
    // fault containment (preempt-and-requeue vs fail), cancellation and
    // deadlines, over the real slot table + pager:
    //   - every submitted request reaches EXACTLY one terminal event
    //     (done / failed / canceled / deadline) — a preemption requeue
    //     is not terminal and must not duplicate one
    //   - no slot or page leaks: after the drain the table is empty and
    //     every page is back in the pool
    //   - containment only ever requeues a decoding slot that has
    //     emitted tokens; its re-prefill covers the full token history
    use std::collections::{BTreeMap, VecDeque};

    #[derive(Clone)]
    struct Queued {
        id: u64,
        n_prompt: usize,
        max_new: usize,
        deadline_op: Option<usize>,
    }

    let mut rng = Rng::new(0x11FE_C7C1);
    for case in 0..30 {
        let page_size = [4usize, 8][rng.below(2)];
        let blocks_per_slot = 2 + rng.below(3);
        let smax = page_size * blocks_per_slot;
        let batch = 1 + rng.below(4);
        // pools from one-slot-tight to fully provisioned
        let n_pages =
            blocks_per_slot + rng.below(batch * blocks_per_slot + 1);
        let mut pager =
            Pager::new(n_pages, page_size, batch, blocks_per_slot);
        let mut table = SlotTable::new(batch, smax);
        let mut queue: VecDeque<Queued> = VecDeque::new();
        let mut terminals: BTreeMap<u64, &'static str> = BTreeMap::new();
        let mut next_id = 0u64;
        let mut submitted = 0u64;
        let terminal = |terminals: &mut BTreeMap<u64, &'static str>,
                        id: u64,
                        what: &'static str| {
            assert!(
                terminals.insert(id, what).is_none(),
                "request {id} got a second terminal event ({what}) \
                 (case {case})"
            );
        };
        let reserve_for = |q: &Queued| (q.n_prompt + q.max_new).min(smax);

        for op in 0..300 {
            // deadline sweep first, like the engine: queued expired
            // requests error out before any prefill is spent on them
            let mut keep: VecDeque<Queued> = VecDeque::new();
            for q in queue.drain(..) {
                if q.deadline_op.is_some_and(|d| d <= op) {
                    terminal(&mut terminals, q.id, "deadline-queued");
                } else {
                    keep.push_back(q);
                }
            }
            queue = keep;

            match rng.below(6) {
                // submit
                0 => {
                    let id = next_id;
                    next_id += 1;
                    submitted += 1;
                    queue.push_back(Queued {
                        id,
                        n_prompt: 1 + rng.below(smax.min(6)),
                        max_new: 1 + rng.below(6),
                        deadline_op: if rng.chance(0.25) {
                            Some(op + rng.below(40))
                        } else {
                            None
                        },
                    });
                }
                // admit the queue head (FCFS, like burst admission)
                1 => {
                    if let Some(q) = queue.front() {
                        let reserve = reserve_for(q);
                        if table.n_free() > 0 && pager.can_admit(reserve) {
                            let q = queue.pop_front().unwrap();
                            let idx = table
                                .claim(Slot {
                                    request_id: q.id,
                                    pos: q.n_prompt,
                                    n_prompt: q.n_prompt,
                                    n_generated: 0,
                                    max_new_tokens: q.max_new,
                                    temperature: 0.0,
                                    rng_state: q.id,
                                    phase: SlotPhase::Decoding,
                                })
                                .unwrap();
                            pager.admit(idx, q.n_prompt, reserve).unwrap();
                            if let Some(d) = q.deadline_op {
                                // park the deadline on the rng_state
                                // field the simulation does not
                                // otherwise use
                                table.get_mut(idx).unwrap().rng_state =
                                    u64::MAX - d as u64;
                            } else {
                                table.get_mut(idx).unwrap().rng_state = 0;
                            }
                        }
                    }
                }
                // one decode step over every decoding slot
                2 => {
                    for idx in table.decode_indices() {
                        let (id, done, dl) = {
                            let s = table.get_mut(idx).unwrap();
                            // the decode write lands at the old `pos`,
                            // which is always inside the reservation
                            pager.grow(idx, s.pos).unwrap();
                            s.n_generated += 1;
                            s.pos += 1;
                            let expired = s.rng_state != 0
                                && (u64::MAX - s.rng_state) <= op as u64;
                            (
                                s.request_id,
                                s.n_generated >= s.max_new_tokens
                                    || s.pos >= smax,
                                expired,
                            )
                        };
                        if done || dl {
                            table.release(idx);
                            pager.release(idx);
                            terminal(
                                &mut terminals,
                                id,
                                if dl { "deadline-decode" } else { "done" },
                            );
                        }
                    }
                }
                // contained step failure: decoding slots with emitted
                // tokens are preempted and requeued (front), the rest
                // fail — exactly the engine's containment split
                3 => {
                    if rng.chance(0.3) {
                        let mut requeue: Vec<Queued> = Vec::new();
                        for idx in table.active_indices() {
                            let s = table.release(idx).unwrap();
                            pager.release(idx);
                            if s.n_generated > 0 {
                                // re-prefill covers the full history
                                requeue.push(Queued {
                                    id: s.request_id,
                                    n_prompt: s.pos.min(smax),
                                    max_new: s.max_new_tokens
                                        - s.n_generated,
                                    deadline_op: if s.rng_state == 0 {
                                        None
                                    } else {
                                        Some((u64::MAX - s.rng_state)
                                            as usize)
                                    },
                                });
                            } else {
                                terminal(
                                    &mut terminals,
                                    s.request_id,
                                    "failed",
                                );
                            }
                        }
                        for q in requeue.into_iter().rev() {
                            if q.max_new == 0 || q.n_prompt >= smax {
                                // nothing left to decode: the engine
                                // finishes such a slot at readmission
                                terminal(&mut terminals, q.id, "done");
                            } else {
                                queue.push_front(q);
                            }
                        }
                    }
                }
                // cancel a random live request (queued or decoding);
                // canceling an already-terminal id is a no-op
                4 => {
                    if next_id > 0 {
                        let id = rng.below(next_id as usize) as u64;
                        if terminals.contains_key(&id) {
                            // no-op, like Command::Cancel on a finished
                            // request
                        } else if let Some(p) =
                            queue.iter().position(|q| q.id == id)
                        {
                            queue.remove(p);
                            terminal(&mut terminals, id, "canceled");
                        } else if let Some(idx) =
                            table.active_indices().into_iter().find(
                                |&i| {
                                    table
                                        .get(i)
                                        .unwrap()
                                        .request_id
                                        == id
                                },
                            )
                        {
                            table.release(idx);
                            pager.release(idx);
                            terminal(&mut terminals, id, "canceled");
                        }
                    }
                }
                // idle tick (queue waits, nothing decodable)
                _ => {}
            }
        }

        // graceful drain: admit + decode until nothing is queued or
        // active, with a wedge guard — progress must never stall
        let mut steps = 0usize;
        while !queue.is_empty() || table.n_active() > 0 {
            steps += 1;
            assert!(
                steps < 10_000,
                "drain wedged: {} queued, {} active (case {case})",
                queue.len(),
                table.n_active()
            );
            if let Some(q) = queue.front() {
                let reserve = reserve_for(q);
                if table.n_free() > 0 && pager.can_admit(reserve) {
                    let q = queue.pop_front().unwrap();
                    let idx = table
                        .claim(Slot {
                            request_id: q.id,
                            pos: q.n_prompt,
                            n_prompt: q.n_prompt,
                            n_generated: 0,
                            max_new_tokens: q.max_new,
                            temperature: 0.0,
                            rng_state: 0,
                            phase: SlotPhase::Decoding,
                        })
                        .unwrap();
                    pager.admit(idx, q.n_prompt, reserve).unwrap();
                }
            }
            for idx in table.decode_indices() {
                let (id, done) = {
                    let s = table.get_mut(idx).unwrap();
                    pager.grow(idx, s.pos).unwrap();
                    s.n_generated += 1;
                    s.pos += 1;
                    (
                        s.request_id,
                        s.n_generated >= s.max_new_tokens
                            || s.pos >= smax,
                    )
                };
                if done {
                    table.release(idx);
                    pager.release(idx);
                    terminal(&mut terminals, id, "done");
                }
            }
        }

        // exactly one terminal per submitted request, nothing leaked
        assert_eq!(
            terminals.len() as u64,
            submitted,
            "every request needs exactly one terminal event (case {case})"
        );
        assert_eq!(table.n_active(), 0);
        assert_eq!(pager.used_pages(), 0, "page leak (case {case})");
        assert_eq!(pager.free_pages(), n_pages);
    }
}

#[test]
fn prop_trace_lifecycle() {
    // Simulated serving traffic through the trace ring: randomly
    // interleaved request lifecycles — queued terminals (deadline /
    // cancel / head-reject), claim, chunked or whole-prompt prefill,
    // decode, preempt-and-reclaim, every terminal outcome — stamped on
    // one non-decreasing clock must satisfy `trace::check_spans`, with
    // interleaved Step/Retry records ignored; and appending a second
    // terminal for any request must be rejected.
    use ao::coordinator::trace::{
        check_spans, StepKind, TraceBuffer, TraceEvent,
    };
    use std::collections::VecDeque;

    // one request's scripted events, time-free until emission
    #[derive(Clone)]
    enum S {
        Enq(usize),
        Claim(usize),
        Chunk(usize, usize),
        Dec,
        Fin(&'static str),
    }

    check(
        "trace-lifecycle",
        40,
        |r| r.below(1_000_000),
        |&seed| {
            let mut rng = Rng::new(0xBEEF ^ seed as u64);
            let n_req = 1 + rng.below(12);
            let mut scripts: Vec<VecDeque<S>> = Vec::new();
            for _ in 0..n_req {
                let n_prompt = 1 + rng.below(8);
                let mut s = VecDeque::new();
                s.push_back(S::Enq(n_prompt));
                if rng.chance(0.2) {
                    // terminal while still queued: expired deadline,
                    // client cancel, or a batcher head-reject
                    let out =
                        ["deadline", "canceled", "rejected"][rng.below(3)];
                    s.push_back(S::Fin(out));
                    scripts.push(s);
                    continue;
                }
                // 1 + preemptions claim/prefill/decode rounds; a requeue
                // re-enters via the front of the queue WITHOUT a second
                // Enqueued (double Claimed is legal)
                let rounds = 1 + rng.below(3);
                for round in 0..rounds {
                    s.push_back(S::Claim(rng.below(4)));
                    // resumed prompts grow by the tokens emitted so far
                    let len = n_prompt + 2 * round;
                    if rng.chance(0.5) {
                        // scheduler path: chunked prefill
                        let mut start = 0;
                        while start < len {
                            let take = 1 + rng.below(len - start);
                            s.push_back(S::Chunk(start, take));
                            start += take;
                        }
                    } // else whole-prompt admission: no chunk events
                    s.push_back(S::Dec);
                }
                let out = ["eos", "length", "context_full", "failed",
                           "canceled"][rng.below(5)];
                s.push_back(S::Fin(out));
                scripts.push(s);
            }
            let total: usize = scripts.iter().map(|s| s.len()).sum();

            let mut buf = TraceBuffer::new(4096);
            let mut t: u64 = 0;
            while let Some(pick) = {
                let live: Vec<usize> = (0..scripts.len())
                    .filter(|&i| !scripts[i].is_empty())
                    .collect();
                if live.is_empty() {
                    None
                } else {
                    Some(live[rng.below(live.len())])
                }
            } {
                // non-decreasing, NOT strictly increasing: events from
                // one engine step share a microsecond
                if !rng.chance(0.3) {
                    t += 1 + rng.below(40) as u64;
                }
                let id = pick as u64;
                let ev = match scripts[pick].pop_front().unwrap() {
                    S::Enq(n) => {
                        TraceEvent::Enqueued { id, t_us: t, n_prompt: n }
                    }
                    S::Claim(slot) => {
                        TraceEvent::Claimed { id, t_us: t, slot }
                    }
                    S::Chunk(start, take) => TraceEvent::PrefillChunk {
                        id,
                        t_us: t,
                        start,
                        take,
                    },
                    S::Dec => TraceEvent::Decoding { id, t_us: t },
                    S::Fin(out) => TraceEvent::Finished {
                        id,
                        t_us: t,
                        outcome: out.into(),
                    },
                };
                buf.record(ev);
                // engine-level records carry no request id and must be
                // invisible to the span checker
                if rng.chance(0.15) {
                    buf.record(TraceEvent::Step {
                        step: t,
                        t_us: t,
                        kind: StepKind::Mixed,
                        rows: rng.below(4),
                        tokens: rng.below(64),
                        exec_us: 10,
                        h2d_bytes: 0,
                        d2h_bytes: 0,
                        retries: 0,
                        preemptions: 0,
                        prefix_hits: 0,
                        pages_used: 0,
                    });
                }
                if rng.chance(0.05) {
                    buf.record(TraceEvent::Retry {
                        t_us: t,
                        site: "exec".into(),
                        tag: "decode".into(),
                        attempt: 1,
                        delay_ms: 1,
                    });
                }
            }
            if buf.dropped() != 0 {
                return Err(format!(
                    "ring dropped {} events under capacity", buf.dropped()
                ));
            }
            if buf.len() < total {
                return Err(format!(
                    "recorded {} < scripted {total}", buf.len()
                ));
            }
            check_spans(buf.events())
                .map_err(|e| format!("well-formed trace rejected: {e}"))?;
            // a second terminal for any request must be caught
            buf.record(TraceEvent::Finished {
                id: rng.below(n_req) as u64,
                t_us: t + 1,
                outcome: "eos".into(),
            });
            if check_spans(buf.events()).is_ok() {
                return Err("double terminal must be rejected".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_windowed_merge_matches_flat_histogram() {
    // rolling-SLO spec: merging a WindowedHistogram's live windows must
    // equal one LogHistogram fed exactly the samples whose window is
    // still inside the ring's horizon — bucket counts identical, and
    // anything older than n_windows windows must have dropped out. The
    // flat side restates the semantics declaratively; the windowed side
    // goes through the ring's lapping/lazy-reset mechanics.
    use ao::util::stats::{LogHistogram, WindowedHistogram};
    const N_WINDOWS: usize = 8;
    const WINDOW_US: u64 = 1_000;
    check(
        "windowed-merge-flat",
        60,
        |r| {
            let n = 1 + r.below(64);
            (0..n)
                .map(|_| (r.below(3_000), r.f32().abs() + 1e-6))
                .collect::<Vec<(usize, f32)>>()
        },
        |steps| {
            if steps.is_empty() {
                return Ok(());
            }
            let mut w = WindowedHistogram::new(N_WINDOWS, WINDOW_US);
            let mut t = 0u64;
            let mut samples: Vec<(u64, f64)> = Vec::new();
            for &(dt, v) in steps {
                t += dt as u64;
                w.record(t, v as f64);
                samples.push((t, v as f64));
            }
            let now = t;
            let horizon = now / WINDOW_US;
            let mut flat = LogHistogram::new();
            for &(ts, v) in &samples {
                if ts / WINDOW_US + N_WINDOWS as u64 > horizon {
                    flat.record(v);
                }
            }
            let span_us = (now + 1).max(WINDOW_US * N_WINDOWS as u64 * 2);
            let merged = w.merged_last(now, span_us);
            if merged.sparse_counts() != flat.sparse_counts() {
                return Err(format!(
                    "merged buckets {:?} != flat buckets {:?}",
                    merged.sparse_counts(),
                    flat.sparse_counts()
                ));
            }
            // expiry: once the run outlives the ring, the oldest
            // sample's window must be gone from the merge
            let first_window = samples.first().map(|&(ts, _)| ts / WINDOW_US);
            if first_window
                .is_some_and(|fw| horizon.saturating_sub(fw) >= N_WINDOWS as u64)
                && merged.len() == samples.len() as u64
            {
                return Err(
                    "a window older than the ring horizon never expired"
                        .to_string(),
                );
            }
            Ok(())
        },
    );
}
