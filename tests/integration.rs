//! End-to-end integration over real artifacts (requires `make artifacts`).
//!
//! Tests skip (with a notice) when artifacts/manifest.json is missing so
//! `cargo test` stays usable before the first AOT build.

use ao::ckpt::Checkpoint;
use ao::coordinator::{
    engine, CacheScheme, ErrorKind, Event, FinishReason, KvLayout,
    SubmitReq,
};
use ao::data::corpus::standard_corpus;
use ao::data::dataset::PackedDataset;
use ao::evalh::Evaluator;
use ao::quant::{quantize_checkpoint, QuantConfig};
use ao::runtime::Runtime;
use ao::tensor::HostTensor;
use ao::tokenizer::Tokenizer;
use ao::train::Trainer;
use std::path::{Path, PathBuf};
use std::sync::mpsc::channel;
use std::time::Instant;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = ao::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] no artifacts; run `make artifacts`");
        None
    }
}

fn tiny_master_ckpt(dir: &Path) -> Checkpoint {
    // deterministic init without any training
    let trainer = Trainer::new(dir, "tiny", "bf16", 1).expect("trainer");
    trainer.export_checkpoint().expect("export")
}

#[test]
fn runtime_loads_and_runs_prefill() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Runtime::open(&dir).unwrap();
    let specs = runtime.manifest.find("prefill", "tiny", Some("f32"));
    assert!(!specs.is_empty());
    let spec = specs[0].clone();
    // zero-filled inputs of the right shapes
    let inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| {
            let mut t = HostTensor::zeros(
                ao::tensor::DType::parse(&s.dtype).unwrap(),
                s.shape.clone(),
            );
            if s.name == "lens" {
                t = HostTensor::s32(
                    s.shape.clone(),
                    vec![1i32; s.shape.iter().product()],
                );
            }
            t
        })
        .collect();
    let outs = runtime.run_host(&spec.name, &inputs).unwrap();
    assert_eq!(outs.len(), spec.outputs.len());
    assert_eq!(outs[0].shape, spec.outputs[0].shape);
}

#[test]
fn trainer_loss_decreases_on_repeated_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let mut trainer = Trainer::new(&dir, "tiny", "bf16", 2).unwrap();
    let corpus = standard_corpus(3, 64 * 1024, 0);
    let tok = Tokenizer::byte_level();
    let ds = PackedDataset::from_text(&tok, &corpus.train, trainer.seq());
    let mut rng = ao::util::rng::Rng::new(0);
    let batch = ds.sample_batch(&mut rng, trainer.batch());
    let first = trainer.step_on(batch.clone()).unwrap();
    let mut last = first;
    for _ in 0..6 {
        last = trainer.step_on(batch.clone()).unwrap();
    }
    assert!(first.is_finite() && last.is_finite());
    assert!(
        last < first,
        "loss should fall on a repeated batch: {first} -> {last}"
    );
}

#[test]
fn quantize_then_eval_all_schemes() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let runtime = Runtime::open(&dir).unwrap();
    let corpus = standard_corpus(5, 8 * 1024, 8 * 1024);
    let tok = Tokenizer::byte_level();
    let ids = tok.encode(&corpus.val);
    let n_words = corpus.val.split_whitespace().count();

    // f32 baseline
    let ev = Evaluator::new(&runtime, "tiny", "f32", &master).unwrap();
    let base = ev.perplexity(&ids, n_words, 2).unwrap();
    assert!(base.token_ppl.is_finite() && base.token_ppl > 1.0);

    // every packed scheme the tiny model ships with
    for tag in ["8da4w-32"] {
        let cfg = QuantConfig::parse(tag).unwrap();
        let (packed, report) = quantize_checkpoint(&master, cfg).unwrap();
        assert!(report.packed_bytes < report.f32_bytes);
        let ev = Evaluator::new(&runtime, "tiny", tag, &packed).unwrap();
        let ppl = ev.perplexity(&ids, n_words, 2).unwrap();
        assert!(ppl.token_ppl.is_finite());
        // untrained random-init model: quantization should not blow up ppl
        assert!(
            ppl.token_ppl < base.token_ppl * 2.0,
            "{tag}: {} vs {}", ppl.token_ppl, base.token_ppl
        );
    }
}

#[test]
fn engine_serves_batched_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32.aockpt");
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });

    let mut rxs = Vec::new();
    for i in 0..5u64 {
        let (tx, rx) = channel();
        handle
            .submit(SubmitReq {
                id: i,
                prompt_tokens: vec![65 + i as u32; 4 + i as usize],
                max_new_tokens: 6,
                temperature: if i % 2 == 0 { 0.0 } else { 0.8 },
                seed: i,
                tx,
                submitted_at: Instant::now(),
                enqueued_at: None,
                resume: None,
                deadline: None,
            })
            .unwrap();
        rxs.push(rx);
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let mut tokens = 0;
        let mut done = false;
        for ev in rx {
            match ev {
                Event::Token(_) => tokens += 1,
                Event::Done(info) => {
                    assert_eq!(info.n_generated, tokens, "req {i}");
                    assert_eq!(info.n_generated, 6, "req {i}");
                    done = true;
                }
                Event::Error(e) => panic!("req {i} error: {e}"),
            }
        }
        assert!(done, "req {i} never finished");
    }
    handle.shutdown();
    let metrics = join.join().unwrap().unwrap();
    assert_eq!(metrics.n_requests, 5);
    assert_eq!(metrics.n_output_tokens, 30);
    assert!(metrics.occupancy() > 0.0);
}

#[test]
fn engine_greedy_decode_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_det.aockpt");
    master.save(&ckpt_path).unwrap();

    let run_once = || -> Vec<u32> {
        let (handle, join) = engine::spawn(engine::EngineConfig {
            artifacts_dir: dir.clone(),
            ckpt_path: ckpt_path.clone(),
            model: "tiny".into(),
            scheme: "f32".into(),
            cache_scheme: CacheScheme::F32,
            kv_layout: KvLayout::Static,
            eos_token: None,
            host_admission: false,
            prefix_cache: false,
            max_batch_tokens: None,
            fault_retries: 3,
            fault_backoff_ms: 1,
            fault_plan: None,
            max_queue: None,
            default_deadline_ms: None,
            trace: false,
            trace_capacity: 0,
            trace_out: None,
            fault_jitter_ms: 0,
            bounded_stats: false,
            metrics_out: None,
            postmortem_dir: None,
            slo_window_secs: 0,
            slo_windows: 0,
        });
        let (tx, rx) = channel();
        handle
            .submit(SubmitReq {
                id: 0,
                prompt_tokens: vec![10, 20, 30, 40, 50],
                max_new_tokens: 8,
                temperature: 0.0,
                seed: 0,
                tx,
                submitted_at: Instant::now(),
                enqueued_at: None,
                resume: None,
                deadline: None,
            })
            .unwrap();
        let mut out = Vec::new();
        for ev in rx {
            if let Event::Token(t) = ev {
                out.push(t);
            }
        }
        handle.shutdown();
        join.join().unwrap().unwrap();
        out
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(a, b, "greedy decode must be deterministic");
    assert_eq!(a.len(), 8);
}

/// Tentpole acceptance: with the KV cache device-resident, the decode hot
/// path's host traffic is exactly one logits matrix down and two s32
/// vectors (token, pos) up per step — never the cache.
#[test]
fn decode_host_traffic_is_logits_only() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_xfer.aockpt");
    master.save(&ckpt_path).unwrap();

    let runtime = Runtime::open(&dir).unwrap();
    let decode = runtime.manifest.find("decode", "tiny", Some("f32"))[0];
    let logits_bytes = decode.outputs[0].byte_size().unwrap() as u64;
    let batch = decode.batch as u64;
    let cache_bytes = decode.inputs[decode.input_index("kcache").unwrap()]
        .byte_size()
        .unwrap() as u64;
    drop(runtime);

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    let mut rxs = Vec::new();
    for i in 0..3u64 {
        let (tx, rx) = channel();
        handle
            .submit(SubmitReq {
                id: i,
                prompt_tokens: vec![40 + i as u32; 6],
                max_new_tokens: 8,
                temperature: 0.0,
                seed: i,
                tx,
                submitted_at: Instant::now(),
                enqueued_at: None,
                resume: None,
                deadline: None,
            })
            .unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        for ev in rx {
            if matches!(ev, Event::Done(_) | Event::Error(_)) {
                break;
            }
        }
    }
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert!(m.decode_steps > 0);
    assert_eq!(
        m.decode_d2h_bytes,
        m.decode_steps as u64 * logits_bytes,
        "per decode step, exactly one [B, vocab] logits download"
    );
    assert_eq!(
        m.decode_h2d_bytes,
        m.decode_steps as u64 * 2 * batch * 4,
        "per decode step, exactly token + pos vectors uploaded"
    );
    assert!(
        m.decode_d2h_per_step() < cache_bytes as f64,
        "decode must not round-trip the cache"
    );
}

/// Regression (off-by-one): a prompt of smax-1 tokens still has one cache
/// position to write — the request must generate until the cache is
/// actually full, then finish with ContextFull.
#[test]
fn context_cap_grants_the_last_cache_slot() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_ctx.aockpt");
    master.save(&ckpt_path).unwrap();

    let runtime = Runtime::open(&dir).unwrap();
    let decode = runtime.manifest.find("decode", "tiny", Some("f32"))[0];
    let smax = decode.smax;
    let max_bucket = runtime
        .manifest
        .find("prefill", "tiny", Some("f32"))
        .iter()
        .map(|s| s.seq)
        .max()
        .unwrap();
    drop(runtime);
    let n_prompt = (smax - 1).min(max_bucket);

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    let (tx, rx) = channel();
    handle
        .submit(SubmitReq {
            id: 1,
            prompt_tokens: vec![66; n_prompt],
            max_new_tokens: smax,
            temperature: 0.0,
            seed: 1,
            tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: None,
        })
        .unwrap();
    let mut n_tokens = 0usize;
    let mut finish = None;
    for ev in rx {
        match ev {
            Event::Token(_) => n_tokens += 1,
            Event::Done(info) => {
                finish = Some(info);
                break;
            }
            Event::Error(e) => panic!("error: {e}"),
        }
    }
    handle.shutdown();
    join.join().unwrap().unwrap();
    let info = finish.expect("request never finished");
    assert_eq!(info.reason, FinishReason::ContextFull);
    // prompt fills positions 0..n_prompt; generation writes the rest plus
    // samples one final token off the full cache
    assert_eq!(info.n_generated, smax - n_prompt + 1);
    assert_eq!(info.n_generated, n_tokens);
}

/// Regression (admission stall): an oversized head prompt is rejected and
/// the requests queued behind it are admitted in the same burst.
#[test]
fn oversized_head_does_not_stall_admission() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_stall.aockpt");
    master.save(&ckpt_path).unwrap();

    let runtime = Runtime::open(&dir).unwrap();
    let max_bucket = runtime
        .manifest
        .find("prefill", "tiny", Some("f32"))
        .iter()
        .map(|s| s.seq)
        .max()
        .unwrap();
    drop(runtime);

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    // head: too long for any bucket; followers: ordinary prompts
    let (bad_tx, bad_rx) = channel();
    handle
        .submit(SubmitReq {
            id: 0,
            prompt_tokens: vec![65; max_bucket + 1],
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 0,
            tx: bad_tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: None,
        })
        .unwrap();
    let mut rxs = Vec::new();
    for i in 1..3u64 {
        let (tx, rx) = channel();
        handle
            .submit(SubmitReq {
                id: i,
                prompt_tokens: vec![70 + i as u32; 5],
                max_new_tokens: 4,
                temperature: 0.0,
                seed: i,
                tx,
                submitted_at: Instant::now(),
                enqueued_at: None,
                resume: None,
                deadline: None,
            })
            .unwrap();
        rxs.push(rx);
    }
    let mut saw_error = false;
    for ev in bad_rx {
        if let Event::Error(e) = ev {
            assert!(e.message.contains("exceeds"));
            saw_error = true;
            break;
        }
    }
    assert!(saw_error, "oversized prompt must be answered with an error");
    for (i, rx) in rxs.into_iter().enumerate() {
        let mut done = false;
        for ev in rx {
            match ev {
                Event::Done(info) => {
                    assert_eq!(info.n_generated, 4, "req {i}");
                    done = true;
                }
                Event::Error(e) => panic!("req {i} error: {e}"),
                Event::Token(_) => {}
            }
        }
        assert!(done, "follower {i} stalled behind rejected head");
    }
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert_eq!(m.n_rejected, 1);
    assert_eq!(m.n_requests, 2);
    assert!(
        m.ttft_s.len() == 2,
        "rejected request must not record a TTFT"
    );
}

/// True when the artifact dir carries admit artifacts for (tiny, f32)
/// under `cache_scheme`; otherwise prints a skip notice.
fn has_admit_artifacts(dir: &Path, cache_scheme: CacheScheme) -> bool {
    let runtime = Runtime::open(dir).unwrap();
    let found = runtime
        .manifest
        .find("admit", "tiny", Some("f32"))
        .iter()
        .any(|s| s.cache == cache_scheme.tag());
    if !found {
        eprintln!(
            "[skip] no admit artifacts for kv-cache {}; re-run `make \
             artifacts`",
            cache_scheme.tag()
        );
    }
    found
}

/// Tentpole acceptance body: with an admit artifact, a prefill burst
/// performs ZERO whole-cache host transfers — admission uploads only the
/// token/len/slot-id vectors and downloads only one logits matrix per
/// prefill call, REGARDLESS of the cache scheme. (Requires artifacts
/// exported with the admit kind; skips on older artifact dirs.)
fn admission_rows_only_under(cache_scheme: CacheScheme) {
    let Some(dir) = artifacts_dir() else { return };
    if !has_admit_artifacts(&dir, cache_scheme) {
        return;
    }
    let runtime = Runtime::open(&dir).unwrap();
    let bucket = runtime
        .manifest
        .find("prefill", "tiny", Some("f32"))
        .iter()
        .map(|s| s.seq)
        .filter(|&b| b >= 6)
        .min()
        .unwrap();
    let admit = runtime
        .manifest
        .find("admit", "tiny", Some("f32"))
        .into_iter()
        .find(|s| s.seq == bucket && s.cache == cache_scheme.tag())
        .expect("admit artifact for every prefill bucket")
        .clone();
    let logits_bytes = admit.outputs[0].byte_size().unwrap() as u64;
    let batch = admit.batch as u64;
    let cache_bytes: u64 = admit
        .cache_input_names()
        .unwrap()
        .iter()
        .map(|n| {
            admit.inputs[admit.input_index(n).unwrap()]
                .byte_size()
                .unwrap() as u64
        })
        .sum();
    drop(runtime);

    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path =
        tmp.join(format!("tiny_f32_admit_{}.aockpt", cache_scheme.tag()));
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    let mut rxs = Vec::new();
    for i in 0..3u64 {
        let (tx, rx) = channel();
        handle
            .submit(SubmitReq {
                id: i,
                prompt_tokens: vec![50 + i as u32; 6],
                max_new_tokens: 5,
                temperature: 0.0,
                seed: i,
                tx,
                submitted_at: Instant::now(),
                enqueued_at: None,
                resume: None,
                deadline: None,
            })
            .unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        for ev in rx {
            if matches!(ev, Event::Done(_) | Event::Error(_)) {
                break;
            }
        }
    }
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert!(m.prefill_calls > 0);
    assert_eq!(m.host_splice_bursts, 0, "device path must not host-splice");
    assert_eq!(
        m.admit_d2h_bytes,
        m.prefill_calls as u64 * logits_bytes,
        "per prefill call, exactly one [B, vocab] logits download — the \
         cache never comes down"
    );
    assert_eq!(
        m.admit_h2d_bytes,
        m.prefill_calls as u64 * (batch * bucket as u64 + 2 * batch) * 4,
        "admission uploads only the token matrix + len/slot-id vectors"
    );
    assert!(
        m.admit_d2h_bytes < cache_bytes,
        "cache-sized admission D2H means the splice fallback ran"
    );
    assert_eq!(m.cache_scheme, cache_scheme.tag());
}

#[test]
fn admission_host_traffic_is_rows_only() {
    admission_rows_only_under(CacheScheme::F32);
}

/// The int8 cache shrinks the resident allocation, it must not grow the
/// admission traffic: the rows-only gate holds bit-identically.
#[test]
fn admission_host_traffic_is_rows_only_under_int8() {
    admission_rows_only_under(CacheScheme::Int8);
}

/// The device scatter and the host splice fallback are interchangeable
/// under either cache scheme: the same greedy workload produces
/// identical token streams on both paths (and the fallback really is
/// exercised when forced). Under int8 this pins the host-side
/// `splice_kv_quantized` numerics to the admit graph's on-device
/// quantize+scatter.
fn admission_paths_agree_under(cache_scheme: CacheScheme) {
    let Some(dir) = artifacts_dir() else { return };
    if !has_admit_artifacts(&dir, cache_scheme) {
        return;
    }
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path =
        tmp.join(format!("tiny_f32_parity_{}.aockpt", cache_scheme.tag()));
    master.save(&ckpt_path).unwrap();

    let run = |host_admission: bool| -> (Vec<Vec<u32>>, usize) {
        let (handle, join) = engine::spawn(engine::EngineConfig {
            artifacts_dir: dir.clone(),
            ckpt_path: ckpt_path.clone(),
            model: "tiny".into(),
            scheme: "f32".into(),
            cache_scheme,
            kv_layout: KvLayout::Static,
            eos_token: None,
            host_admission,
            prefix_cache: false,
            max_batch_tokens: None,
            fault_retries: 3,
            fault_backoff_ms: 1,
            fault_plan: None,
            max_queue: None,
            default_deadline_ms: None,
            trace: false,
            trace_capacity: 0,
            trace_out: None,
            fault_jitter_ms: 0,
            bounded_stats: false,
            metrics_out: None,
            postmortem_dir: None,
            slo_window_secs: 0,
            slo_windows: 0,
        });
        let mut rxs = Vec::new();
        for i in 0..4u64 {
            let (tx, rx) = channel();
            handle
                .submit(SubmitReq {
                    id: i,
                    prompt_tokens: vec![30 + 7 * i as u32; 3 + i as usize],
                    max_new_tokens: 6,
                    temperature: 0.0,
                    seed: i,
                    tx,
                    submitted_at: Instant::now(),
                    enqueued_at: None,
                    resume: None,
                    deadline: None,
                })
                .unwrap();
            rxs.push(rx);
        }
        let streams = rxs
            .into_iter()
            .map(|rx| {
                let mut toks = Vec::new();
                for ev in rx {
                    match ev {
                        Event::Token(t) => toks.push(t),
                        Event::Done(_) => break,
                        Event::Error(e) => panic!("error: {e}"),
                    }
                }
                toks
            })
            .collect();
        handle.shutdown();
        let m = join.join().unwrap().unwrap();
        (streams, m.host_splice_bursts)
    };
    let (device_streams, device_splices) = run(false);
    let (host_streams, host_splices) = run(true);
    assert_eq!(device_splices, 0, "device path must not splice");
    assert!(host_splices > 0, "forced fallback must actually splice");
    assert_eq!(
        device_streams, host_streams,
        "both admission paths must write identical cache rows"
    );
}

#[test]
fn admission_device_and_host_paths_agree() {
    admission_paths_agree_under(CacheScheme::F32);
}

#[test]
fn admission_device_and_host_paths_agree_under_int8() {
    admission_paths_agree_under(CacheScheme::Int8);
}

/// Tentpole acceptance (quantized KV cache): the same scripted greedy
/// workload served under the f32 and int8 cache schemes produces
/// identical token streams, while the int8 cache's resident footprint is
/// a fraction of the f32 one (Dh+4 vs 4*Dh bytes per cached position —
/// ~3.2x on tiny's Dh=16, ~3.6x on small's Dh=32; the table1 bench
/// prints the per-scheme accounting).
#[test]
fn kv_cache_schemes_agree() {
    let Some(dir) = artifacts_dir() else { return };
    if !has_admit_artifacts(&dir, CacheScheme::Int8) {
        return;
    }
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_kv8.aockpt");
    master.save(&ckpt_path).unwrap();

    let run = |cache_scheme: CacheScheme| -> (Vec<Vec<u32>>, u64) {
        let (handle, join) = engine::spawn(engine::EngineConfig {
            artifacts_dir: dir.clone(),
            ckpt_path: ckpt_path.clone(),
            model: "tiny".into(),
            scheme: "f32".into(),
            cache_scheme,
            kv_layout: KvLayout::Static,
            eos_token: None,
            host_admission: false,
            prefix_cache: false,
            max_batch_tokens: None,
            fault_retries: 3,
            fault_backoff_ms: 1,
            fault_plan: None,
            max_queue: None,
            default_deadline_ms: None,
            trace: false,
            trace_capacity: 0,
            trace_out: None,
            fault_jitter_ms: 0,
            bounded_stats: false,
            metrics_out: None,
            postmortem_dir: None,
            slo_window_secs: 0,
            slo_windows: 0,
        });
        let mut rxs = Vec::new();
        for i in 0..5u64 {
            let (tx, rx) = channel();
            handle
                .submit(SubmitReq {
                    id: i,
                    prompt_tokens: vec![20 + 9 * i as u32; 3 + i as usize],
                    max_new_tokens: 8,
                    temperature: 0.0,
                    seed: i,
                    tx,
                    submitted_at: Instant::now(),
                    enqueued_at: None,
                    resume: None,
                    deadline: None,
                })
                .unwrap();
            rxs.push(rx);
        }
        let streams = rxs
            .into_iter()
            .map(|rx| {
                let mut toks = Vec::new();
                for ev in rx {
                    match ev {
                        Event::Token(t) => toks.push(t),
                        Event::Done(_) => break,
                        Event::Error(e) => panic!("error: {e}"),
                    }
                }
                toks
            })
            .collect();
        handle.shutdown();
        let m = join.join().unwrap().unwrap();
        (streams, m.cache_resident_bytes)
    };
    let (f32_streams, f32_bytes) = run(CacheScheme::F32);
    let (int8_streams, int8_bytes) = run(CacheScheme::Int8);
    assert_eq!(
        f32_streams, int8_streams,
        "int8 KV quantization must not change the greedy token streams \
         of this workload"
    );
    assert!(
        int8_bytes * 3 <= f32_bytes,
        "int8 cache must be at least 3x smaller resident: {int8_bytes} \
         vs {f32_bytes}"
    );
}

/// True when the artifact dir carries paged decode+admit artifacts for
/// (tiny, f32) under `cache_scheme`; otherwise prints a skip notice.
fn has_paged_artifacts(dir: &Path, cache_scheme: CacheScheme) -> bool {
    let runtime = Runtime::open(dir).unwrap();
    let found = ["decode", "admit"].iter().all(|&kind| {
        runtime
            .manifest
            .find(kind, "tiny", Some("f32"))
            .iter()
            .any(|s| s.cache == cache_scheme.tag() && s.layout == "paged")
    });
    if !found {
        eprintln!(
            "[skip] no paged artifacts for kv-cache {}; re-run `make \
             artifacts`",
            cache_scheme.tag()
        );
    }
    found
}

/// Tentpole acceptance (paged KV cache): the same scripted greedy
/// workload produces identical token streams under --kv-layout=static
/// and --kv-layout=paged for BOTH cache schemes, while the paged page
/// pool is resident-smaller than the static [B, Smax] reservation and
/// the pager actually cycled pages (hwm > 0, all released at the end).
#[test]
fn kv_layouts_agree() {
    let Some(dir) = artifacts_dir() else { return };
    for cache_scheme in [CacheScheme::F32, CacheScheme::Int8] {
        if !has_admit_artifacts(&dir, cache_scheme)
            || !has_paged_artifacts(&dir, cache_scheme)
        {
            return;
        }
        let master = tiny_master_ckpt(&dir);
        let tmp = std::env::temp_dir().join("ao_int_tests");
        std::fs::create_dir_all(&tmp).unwrap();
        let ckpt_path =
            tmp.join(format!("tiny_f32_layout_{}.aockpt", cache_scheme.tag()));
        master.save(&ckpt_path).unwrap();

        let run = |kv_layout: KvLayout| {
            let (handle, join) = engine::spawn(engine::EngineConfig {
                artifacts_dir: dir.clone(),
                ckpt_path: ckpt_path.clone(),
                model: "tiny".into(),
                scheme: "f32".into(),
                cache_scheme,
                kv_layout,
                eos_token: None,
                host_admission: false,
                prefix_cache: false,
                max_batch_tokens: None,
                fault_retries: 3,
                fault_backoff_ms: 1,
                fault_plan: None,
                max_queue: None,
                default_deadline_ms: None,
                trace: false,
                trace_capacity: 0,
                trace_out: None,
                fault_jitter_ms: 0,
                bounded_stats: false,
                metrics_out: None,
                postmortem_dir: None,
                slo_window_secs: 0,
                slo_windows: 0,
            });
            let mut rxs = Vec::new();
            // mixed short/long greedy workload, more requests than fit at
            // once so slots (and pages) are recycled
            for i in 0..10u64 {
                let (tx, rx) = channel();
                handle
                    .submit(SubmitReq {
                        id: i,
                        prompt_tokens: vec![
                            15 + 5 * i as u32;
                            2 + (3 * i as usize) % 11
                        ],
                        max_new_tokens: 4 + (i as usize % 3) * 3,
                        temperature: 0.0,
                        seed: i,
                        tx,
                        submitted_at: Instant::now(),
                        enqueued_at: None,
                        resume: None,
                        deadline: None,
                    })
                    .unwrap();
                rxs.push(rx);
            }
            let streams: Vec<Vec<u32>> = rxs
                .into_iter()
                .map(|rx| {
                    let mut toks = Vec::new();
                    for ev in rx {
                        match ev {
                            Event::Token(t) => toks.push(t),
                            Event::Done(_) => break,
                            Event::Error(e) => panic!("error: {e}"),
                        }
                    }
                    toks
                })
                .collect();
            handle.shutdown();
            let m = join.join().unwrap().unwrap();
            (streams, m)
        };
        let (static_streams, static_m) = run(KvLayout::Static);
        let (paged_streams, paged_m) = run(KvLayout::Paged);
        assert_eq!(
            static_streams,
            paged_streams,
            "paging must not change the greedy token streams \
             (kv-cache {})",
            cache_scheme.tag()
        );
        assert!(
            paged_m.cache_resident_bytes < static_m.cache_resident_bytes,
            "the page pool must be resident-smaller than the static \
             cache: {} vs {}",
            paged_m.cache_resident_bytes,
            static_m.cache_resident_bytes
        );
        assert!(paged_m.pages_total > 0);
        assert!(
            paged_m.pages_hwm > 0,
            "the pager must actually have allocated pages"
        );
        assert_eq!(
            paged_m.pages_used, 0,
            "every page returns to the pool once the workload drains"
        );
        assert_eq!(static_m.pages_total, 0, "static engines have no pool");
    }
}

/// True when the artifact dir carries admit_suffix artifacts for
/// (tiny, f32) under `cache_scheme`; otherwise prints a skip notice.
fn has_suffix_artifacts(dir: &Path, cache_scheme: CacheScheme) -> bool {
    let runtime = Runtime::open(dir).unwrap();
    let found = runtime
        .manifest
        .find("admit_suffix", "tiny", Some("f32"))
        .iter()
        .any(|s| s.cache == cache_scheme.tag() && s.layout == "paged");
    if !found {
        eprintln!(
            "[skip] no admit_suffix artifacts for kv-cache {}; re-run \
             `make artifacts`",
            cache_scheme.tag()
        );
    }
    found
}

/// Tentpole acceptance (prefix cache): the same shared-system-prompt
/// greedy workload produces identical token streams with the prefix
/// cache enabled and disabled, under BOTH cache schemes — while the
/// enabled run reports actual sharing (tokens_saved > 0, pages_shared >
/// 0) and a strictly smaller page high-water mark at equal batch,
/// because concurrent requests map one physical copy of the shared
/// prompt's pages instead of allocating one each.
#[test]
fn prefix_cache_agrees() {
    let Some(dir) = artifacts_dir() else { return };
    for cache_scheme in [CacheScheme::F32, CacheScheme::Int8] {
        if !has_paged_artifacts(&dir, cache_scheme)
            || !has_suffix_artifacts(&dir, cache_scheme)
        {
            return;
        }
        let runtime = Runtime::open(&dir).unwrap();
        let decode = runtime
            .manifest
            .find("decode", "tiny", Some("f32"))
            .into_iter()
            .find(|s| s.cache == cache_scheme.tag() && s.layout == "paged")
            .expect("paged decode artifact");
        let ps = decode.page_size;
        drop(runtime);

        let master = tiny_master_ckpt(&dir);
        let tmp = std::env::temp_dir().join("ao_int_tests");
        std::fs::create_dir_all(&tmp).unwrap();
        let ckpt_path = tmp
            .join(format!("tiny_f32_prefix_{}.aockpt", cache_scheme.tag()));
        master.save(&ckpt_path).unwrap();

        // one system prompt spanning a full page (+1 token so the page
        // is shareable), plus a distinct per-request tail
        let system: Vec<u32> = (0..ps as u32 + 1).map(|t| 30 + t).collect();
        let run = |prefix_cache: bool| {
            let (handle, join) = engine::spawn(engine::EngineConfig {
                artifacts_dir: dir.clone(),
                ckpt_path: ckpt_path.clone(),
                model: "tiny".into(),
                scheme: "f32".into(),
                cache_scheme,
                kv_layout: KvLayout::Paged,
                eos_token: None,
                host_admission: false,
                prefix_cache,
                max_batch_tokens: None,
                fault_retries: 3,
                fault_backoff_ms: 1,
                fault_plan: None,
                max_queue: None,
                default_deadline_ms: None,
                trace: false,
                trace_capacity: 0,
                trace_out: None,
                fault_jitter_ms: 0,
                bounded_stats: false,
                metrics_out: None,
                postmortem_dir: None,
                slo_window_secs: 0,
                slo_windows: 0,
            });
            let collect = |rx: std::sync::mpsc::Receiver<Event>| {
                let mut toks = Vec::new();
                for ev in rx {
                    match ev {
                        Event::Token(t) => toks.push(t),
                        Event::Done(_) => break,
                        Event::Error(e) => panic!("error: {e}"),
                    }
                }
                toks
            };
            // phase 1: one seed request writes (and publishes) the
            // system prompt's page
            let (tx, rx) = channel();
            handle
                .submit(SubmitReq {
                    id: 0,
                    prompt_tokens: system.clone(),
                    max_new_tokens: 6,
                    temperature: 0.0,
                    seed: 0,
                    tx,
                    submitted_at: Instant::now(),
                    enqueued_at: None,
                    resume: None,
                    deadline: None,
                })
                .unwrap();
            let mut streams = vec![collect(rx)];
            // phase 2: a concurrent burst of requests sharing the same
            // system prompt with distinct user tails
            let mut rxs = Vec::new();
            for i in 1..=7u64 {
                let mut prompt = system.clone();
                prompt.extend((0..1 + (i as u32 % 3)).map(|j| 90 + 7 * i as u32 + j));
                let (tx, rx) = channel();
                handle
                    .submit(SubmitReq {
                        id: i,
                        prompt_tokens: prompt,
                        max_new_tokens: 6,
                        temperature: 0.0,
                        seed: i,
                        tx,
                        submitted_at: Instant::now(),
                        enqueued_at: None,
                        resume: None,
                        deadline: None,
                    })
                    .unwrap();
                rxs.push(rx);
            }
            streams.extend(rxs.into_iter().map(collect));
            handle.shutdown();
            let m = join.join().unwrap().unwrap();
            (streams, m)
        };
        let (off_streams, off_m) = run(false);
        let (on_streams, on_m) = run(true);
        assert_eq!(
            off_streams,
            on_streams,
            "prefix sharing must not change the greedy token streams \
             (kv-cache {})",
            cache_scheme.tag()
        );
        // the disabled run must not have consulted any index
        assert!(!off_m.prefix_enabled);
        assert_eq!(off_m.prefix_pages_shared, 0);
        // the enabled run actually shared: every burst-2 request maps
        // the seed's system-prompt page instead of re-prefilling it
        assert!(on_m.prefix_enabled);
        assert!(on_m.prefix_lookups > 0, "admissions must consult the index");
        assert!(
            on_m.prefix_pages_shared > 0,
            "the shared-system-prompt burst must map shared pages"
        );
        assert!(
            on_m.prefix_tokens_saved > 0,
            "shared pages cover prompt tokens the suffix prefill skipped"
        );
        assert_eq!(
            on_m.prefix_tokens_saved,
            on_m.prefix_pages_shared * ps,
            "sharing is full-page-only"
        );
        assert!(
            on_m.pages_hwm < off_m.pages_hwm,
            "one physical copy of the shared prefix must shrink the page \
             high-water mark: {} (on) vs {} (off)",
            on_m.pages_hwm,
            off_m.pages_hwm
        );
        // every page still returns to the pool (shared ones via the
        // cached LRU, which used_pages excludes)
        assert_eq!(on_m.pages_used, 0);
        assert_eq!(on_m.n_requests, 8);
    }
}

/// ROADMAP "untupled execution outputs": the binding must hand back one
/// buffer per output tuple element, otherwise the device-resident decode
/// and admission paths silently degrade to metered host round-trips (the
/// transfer gates above would catch the bytes; this pins the capability
/// itself).
#[test]
fn runtime_untuples_execution_outputs() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Runtime::open(&dir).unwrap();
    assert!(
        runtime.untupled_outputs(),
        "execute_b returned a packed tuple; probe ExecuteOptions/\
         untuple_result support in the binding"
    );
}

/// Regression (seed collapse): the engine derived `seed ^ id` per
/// request, which is 0 whenever seed == id (exactly what the server
/// submits) — every temperature-sampled request shared one RNG stream.
#[test]
fn sampled_requests_diverge() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_seed.aockpt");
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    // identical prompts, temperature 1.0, seed == id (the collapsing case)
    let mut rxs = Vec::new();
    for id in 1..=2u64 {
        let (tx, rx) = channel();
        handle
            .submit(SubmitReq {
                id,
                prompt_tokens: vec![77; 4],
                max_new_tokens: 16,
                temperature: 1.0,
                seed: id,
                tx,
                submitted_at: Instant::now(),
                enqueued_at: None,
                resume: None,
                deadline: None,
            })
            .unwrap();
        rxs.push(rx);
    }
    let streams: Vec<Vec<u32>> = rxs
        .into_iter()
        .map(|rx| {
            let mut toks = Vec::new();
            for ev in rx {
                match ev {
                    Event::Token(t) => toks.push(t),
                    Event::Done(_) => break,
                    Event::Error(e) => panic!("error: {e}"),
                }
            }
            toks
        })
        .collect();
    handle.shutdown();
    join.join().unwrap().unwrap();
    assert_eq!(streams[0].len(), 16);
    assert_ne!(
        streams[0], streams[1],
        "two sampled requests with distinct ids must draw from distinct \
         RNG streams"
    );
}

/// Regression (NaN logits): a zero-token prompt produced lens[row] = 0 —
/// a live row attending to zero positions. It must be rejected at
/// admission with an error event, and not stall the requests behind it.
#[test]
fn empty_prompt_is_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_empty.aockpt");
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    let (bad_tx, bad_rx) = channel();
    handle
        .submit(SubmitReq {
            id: 0,
            prompt_tokens: vec![],
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 0,
            tx: bad_tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: None,
        })
        .unwrap();
    let (ok_tx, ok_rx) = channel();
    handle
        .submit(SubmitReq {
            id: 1,
            prompt_tokens: vec![42; 5],
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 1,
            tx: ok_tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: None,
        })
        .unwrap();
    let mut saw_error = false;
    for ev in bad_rx {
        match ev {
            Event::Error(e) => {
                assert!(e.message.contains("empty prompt"), "{e}");
                saw_error = true;
                break;
            }
            ev => panic!("empty prompt must error, got {ev:?}"),
        }
    }
    assert!(saw_error);
    let mut done = false;
    for ev in ok_rx {
        match ev {
            Event::Done(info) => {
                assert_eq!(info.n_generated, 4);
                done = true;
            }
            Event::Error(e) => panic!("follower error: {e}"),
            Event::Token(_) => {}
        }
    }
    assert!(done, "follower stalled behind the rejected empty prompt");
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert_eq!(m.n_rejected, 1);
    assert_eq!(m.n_requests, 1);
}

#[test]
fn hellaswag_eval_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let runtime = Runtime::open(&dir).unwrap();
    let ev = Evaluator::new(&runtime, "tiny", "f32", &master).unwrap();
    let tok = Tokenizer::byte_level();
    let items = ao::data::evaltask::generate(11, 8, 1);
    let acc = ev.hellaswag(&items, &tok).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

/// Tentpole acceptance (iteration-level scheduler): the same mixed
/// decode + long-prompt greedy workload produces identical token
/// streams with the token-budget scheduler enabled and disabled, under
/// BOTH cache schemes and BOTH kv layouts — while the enabled run
/// actually chunks prefill into budgeted pieces (sched_chunks > 0),
/// overlaps decode rows with prefill work inside single steps
/// (sched_mixed_steps > 0), and never lets a decode-capable step idle
/// while prefill is pending (sched_stall_steps == 0, the no-stall
/// accounting gate). Under the paged layout the long-prompt burst must
/// also strictly lower the inter-token p95 versus the burst-FCFS
/// baseline, because prefill no longer monopolizes whole steps between
/// two decode ticks.
#[test]
fn scheduler_agrees() {
    let Some(dir) = artifacts_dir() else { return };
    for cache_scheme in [CacheScheme::F32, CacheScheme::Int8] {
        for kv_layout in [KvLayout::Static, KvLayout::Paged] {
            if kv_layout == KvLayout::Paged
                && (!has_paged_artifacts(&dir, cache_scheme)
                    || !has_suffix_artifacts(&dir, cache_scheme))
            {
                return;
            }
            let master = tiny_master_ckpt(&dir);
            let tmp = std::env::temp_dir().join("ao_int_tests");
            std::fs::create_dir_all(&tmp).unwrap();
            let ckpt_path = tmp.join(format!(
                "tiny_f32_sched_{}_{}.aockpt",
                cache_scheme.tag(),
                kv_layout.tag()
            ));
            master.save(&ckpt_path).unwrap();

            let run = |max_batch_tokens: Option<usize>| {
                let (handle, join) = engine::spawn(engine::EngineConfig {
                    artifacts_dir: dir.clone(),
                    ckpt_path: ckpt_path.clone(),
                    model: "tiny".into(),
                    scheme: "f32".into(),
                    cache_scheme,
                    kv_layout,
                    eos_token: None,
                    host_admission: false,
                    prefix_cache: false,
                    max_batch_tokens,
                    fault_retries: 3,
                    fault_backoff_ms: 1,
                    fault_plan: None,
                    max_queue: None,
                    default_deadline_ms: None,
                    // tracing on: the scheduler-parity gate must hold
                    // with the observer attached
                    trace: true,
                    trace_capacity: 0,
                    trace_out: None,
                    fault_jitter_ms: 0,
                    bounded_stats: false,
                    metrics_out: None,
                    postmortem_dir: None,
                    slo_window_secs: 0,
                    slo_windows: 0,
                });
                let mut rxs = Vec::new();
                // two short-prompt decoders first (they sit in Decoding
                // while everything below prefills) ...
                for i in 0..2u64 {
                    let (tx, rx) = channel();
                    handle
                        .submit(SubmitReq {
                            id: i,
                            prompt_tokens: vec![11 + i as u32; 3],
                            max_new_tokens: 24,
                            temperature: 0.0,
                            seed: i,
                            tx,
                            submitted_at: Instant::now(),
                            enqueued_at: None,
                            resume: None,
                            deadline: None,
                        })
                        .unwrap();
                    rxs.push(rx);
                }
                // ... then a burst of long prompts (90 tokens each,
                // several budget chunks apiece, more than the slot/page
                // capacity so admission recycles)
                for i in 2..12u64 {
                    let (tx, rx) = channel();
                    handle
                        .submit(SubmitReq {
                            id: i,
                            prompt_tokens: (0..90)
                                .map(|j| 20 + ((7 * i as u32 + j) % 200))
                                .collect(),
                            max_new_tokens: 4,
                            temperature: 0.0,
                            seed: i,
                            tx,
                            submitted_at: Instant::now(),
                            enqueued_at: None,
                            resume: None,
                            deadline: None,
                        })
                        .unwrap();
                    rxs.push(rx);
                }
                let streams: Vec<Vec<u32>> = rxs
                    .into_iter()
                    .map(|rx| {
                        let mut toks = Vec::new();
                        for ev in rx {
                            match ev {
                                Event::Token(t) => toks.push(t),
                                Event::Done(_) => break,
                                Event::Error(e) => panic!("error: {e}"),
                            }
                        }
                        toks
                    })
                    .collect();
                handle.shutdown();
                let m = join.join().unwrap().unwrap();
                (streams, m)
            };
            let (off_streams, off_m) = run(None);
            let (on_streams, on_m) = run(Some(48));
            assert_eq!(
                off_streams,
                on_streams,
                "the iteration-level scheduler must not change the \
                 greedy token streams (kv-cache {}, layout {})",
                cache_scheme.tag(),
                kv_layout.tag()
            );
            assert!(!off_m.sched_enabled);
            assert_eq!(off_m.sched_steps, 0);
            assert!(on_m.sched_enabled);
            assert!(on_m.sched_steps > 0);
            assert!(
                on_m.sched_chunks > 0,
                "the budget must have split prefill into chunks \
                 (layout {})",
                kv_layout.tag()
            );
            assert!(
                on_m.sched_mixed_steps > 0,
                "decode rows and prefill chunks must share steps \
                 (layout {})",
                kv_layout.tag()
            );
            assert_eq!(
                on_m.sched_stall_steps, 0,
                "no decode-capable step may idle while prefill is \
                 pending (layout {})",
                kv_layout.tag()
            );
            assert_eq!(on_m.n_requests, 12);
            assert_eq!(off_m.n_requests, 12);
            // queue-wait is stamped at enqueue and recorded at claim on
            // both paths
            assert_eq!(on_m.queue_wait_s.len(), 12);
            if kv_layout == KvLayout::Paged {
                // chunked prefill spreads the long-prompt burst across
                // budgeted steps, so the decoders' worst gaps shrink
                // versus the whole-prompt burst that monopolized steps
                let on_p95 = on_m.itl().p95;
                let off_p95 = off_m.itl().p95;
                assert!(
                    on_p95 < off_p95,
                    "chunked prefill must lower inter-token p95 under \
                     the long-prompt burst: {on_p95:.6}s (sched) vs \
                     {off_p95:.6}s (burst-FCFS, kv-cache {})",
                    cache_scheme.tag()
                );
            }
        }
    }
}

/// Tentpole acceptance (fault containment): a seeded fault plan injects
/// transient decode-exec, admit-exec, and transfer failures mid-workload.
/// The engine loop never exits, every request terminates, and — because
/// every injected fault fires BEFORE the real call and recovers within
/// the retry budget — the token streams are greedy-identical to the
/// fault-free run, under BOTH cache schemes and BOTH kv layouts. The
/// paged pool still drains to zero.
#[test]
fn engine_survives_injected_faults() {
    let Some(dir) = artifacts_dir() else { return };
    let plan = "exec:decode:every=5:n=2,exec:admit:at=2:n=1,\
                transfer:h2d:every=7:n=2,transfer:d2h:at=9:n=1";
    for cache_scheme in [CacheScheme::F32, CacheScheme::Int8] {
        for kv_layout in [KvLayout::Static, KvLayout::Paged] {
            if !has_admit_artifacts(&dir, cache_scheme) {
                return;
            }
            if kv_layout == KvLayout::Paged
                && !has_paged_artifacts(&dir, cache_scheme)
            {
                return;
            }
            let master = tiny_master_ckpt(&dir);
            let tmp = std::env::temp_dir().join("ao_int_tests");
            std::fs::create_dir_all(&tmp).unwrap();
            let ckpt_path = tmp.join(format!(
                "tiny_f32_chaos_{}_{}.aockpt",
                cache_scheme.tag(),
                kv_layout.tag()
            ));
            master.save(&ckpt_path).unwrap();

            let run = |fault_plan: Option<&str>| {
                let (handle, join) = engine::spawn(engine::EngineConfig {
                    artifacts_dir: dir.clone(),
                    ckpt_path: ckpt_path.clone(),
                    model: "tiny".into(),
                    scheme: "f32".into(),
                    cache_scheme,
                    kv_layout,
                    eos_token: None,
                    host_admission: false,
                    prefix_cache: false,
                    max_batch_tokens: None,
                    fault_retries: 3,
                    fault_backoff_ms: 1,
                    fault_plan: fault_plan.map(String::from),
                    max_queue: None,
                    default_deadline_ms: None,
                    // tracing on: fault containment must hold with the
                    // observer attached (and retries land in the trace)
                    trace: true,
                    trace_capacity: 0,
                    trace_out: None,
                    fault_jitter_ms: 0,
                    bounded_stats: false,
                    metrics_out: None,
                    postmortem_dir: None,
                    slo_window_secs: 0,
                    slo_windows: 0,
                });
                let mut rxs = Vec::new();
                // mixed prompt lengths so admission spans buckets (and
                // the admit rule sees several calls)
                for i in 0..6u64 {
                    let (tx, rx) = channel();
                    handle
                        .submit(SubmitReq {
                            id: i,
                            prompt_tokens: vec![
                                25 + 3 * i as u32;
                                3 + (2 * i as usize) % 7
                            ],
                            max_new_tokens: 6,
                            temperature: 0.0,
                            seed: i,
                            tx,
                            submitted_at: Instant::now(),
                            enqueued_at: None,
                            resume: None,
                            deadline: None,
                        })
                        .unwrap();
                    rxs.push(rx);
                }
                let streams: Vec<Vec<u32>> = rxs
                    .into_iter()
                    .enumerate()
                    .map(|(i, rx)| {
                        let mut toks = Vec::new();
                        let mut done = false;
                        for ev in rx {
                            match ev {
                                Event::Token(t) => toks.push(t),
                                Event::Done(_) => {
                                    done = true;
                                    break;
                                }
                                Event::Error(e) => {
                                    panic!("req {i} error: {e}")
                                }
                            }
                        }
                        assert!(done, "req {i} never finished");
                        toks
                    })
                    .collect();
                handle.shutdown();
                let m = join.join().unwrap().unwrap();
                (streams, m)
            };
            let (clean_streams, clean_m) = run(None);
            let (chaos_streams, chaos_m) = run(Some(plan));
            assert_eq!(clean_m.faults_injected, 0);
            assert!(
                chaos_m.faults_injected > 0,
                "the plan must actually fire (kv-cache {}, layout {})",
                cache_scheme.tag(),
                kv_layout.tag()
            );
            assert!(
                chaos_m.faults_retried > 0,
                "injected faults must be retried"
            );
            assert_eq!(
                chaos_m.faults_recovered, chaos_m.faults_injected,
                "every injected fault fires before the real call and \
                 must recover within the retry budget"
            );
            assert_eq!(
                clean_streams,
                chaos_streams,
                "recovered faults must not change the greedy token \
                 streams (kv-cache {}, layout {})",
                cache_scheme.tag(),
                kv_layout.tag()
            );
            assert_eq!(chaos_m.n_requests, 6);
            if kv_layout == KvLayout::Paged {
                assert_eq!(
                    chaos_m.pages_used, 0,
                    "the page pool must drain to zero after the chaos run"
                );
            }
        }
    }
}

/// Retry exhaustion under the static layout (no pager/scheduler, so
/// slot-level containment cannot re-prefill): the affected slots fail
/// with a structured `failed` error, the engine loop survives over a
/// re-zeroed cache, and a follow-up request completes normally.
#[test]
fn exhausted_faults_fail_slots_not_the_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_exhaust.aockpt");
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 0, // exhaust immediately
        fault_backoff_ms: 1,
        fault_plan: Some("exec:decode:at=2".into()),
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    let mut rxs = Vec::new();
    for i in 0..2u64 {
        let (tx, rx) = channel();
        handle
            .submit(SubmitReq {
                id: i,
                prompt_tokens: vec![33 + i as u32; 4],
                max_new_tokens: 6,
                temperature: 0.0,
                seed: i,
                tx,
                submitted_at: Instant::now(),
                enqueued_at: None,
                resume: None,
                deadline: None,
            })
            .unwrap();
        rxs.push(rx);
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let mut failed = false;
        for ev in rx {
            if let Event::Error(e) = ev {
                assert_eq!(e.kind, ErrorKind::Failed, "req {i}: {e}");
                failed = true;
                break;
            }
        }
        assert!(failed, "req {i} must fail when the retry budget is 0");
    }
    // the loop survived and the cache was re-zeroed: fresh work is fine
    let (tx, rx) = channel();
    handle
        .submit(SubmitReq {
            id: 9,
            prompt_tokens: vec![55; 4],
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 9,
            tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: None,
        })
        .unwrap();
    let mut done = false;
    for ev in rx {
        match ev {
            Event::Done(info) => {
                assert_eq!(info.n_generated, 4);
                done = true;
            }
            Event::Error(e) => panic!("follow-up error: {e}"),
            Event::Token(_) => {}
        }
    }
    assert!(done, "the engine must keep serving after containment");
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert_eq!(m.faults_injected, 1);
    assert_eq!(m.faults_retried, 0);
    assert_eq!(m.n_requests, 1, "only the follow-up completed");
}

/// Retry exhaustion under paged + scheduler: decoding slots with emitted
/// tokens are preempted and re-prefilled from their token history over
/// the rebuilt cache — the requests still complete, with token streams
/// greedy-identical to a fault-free run.
#[test]
fn contained_failure_resumes_decoding_slots() {
    let Some(dir) = artifacts_dir() else { return };
    let cache_scheme = CacheScheme::F32;
    if !has_paged_artifacts(&dir, cache_scheme)
        || !has_suffix_artifacts(&dir, cache_scheme)
    {
        return;
    }
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_resume.aockpt");
    master.save(&ckpt_path).unwrap();

    let run = |fault_plan: Option<&str>| {
        let (handle, join) = engine::spawn(engine::EngineConfig {
            artifacts_dir: dir.clone(),
            ckpt_path: ckpt_path.clone(),
            model: "tiny".into(),
            scheme: "f32".into(),
            cache_scheme,
            kv_layout: KvLayout::Paged,
            eos_token: None,
            host_admission: false,
            prefix_cache: false,
            max_batch_tokens: Some(48),
            fault_retries: 0,
            fault_backoff_ms: 1,
            fault_plan: fault_plan.map(String::from),
            max_queue: None,
            default_deadline_ms: None,
            trace: false,
            trace_capacity: 0,
            trace_out: None,
            fault_jitter_ms: 0,
            bounded_stats: false,
            metrics_out: None,
            postmortem_dir: None,
            slo_window_secs: 0,
            slo_windows: 0,
        });
        let mut rxs = Vec::new();
        // short prompts: everything is Decoding (with emitted tokens) by
        // the third decode step, so containment preempts rather than
        // fails
        for i in 0..3u64 {
            let (tx, rx) = channel();
            handle
                .submit(SubmitReq {
                    id: i,
                    prompt_tokens: vec![41 + 2 * i as u32; 3],
                    max_new_tokens: 8,
                    temperature: 0.0,
                    seed: i,
                    tx,
                    submitted_at: Instant::now(),
                    enqueued_at: None,
                    resume: None,
                    deadline: None,
                })
                .unwrap();
            rxs.push(rx);
        }
        let streams: Vec<Vec<u32>> = rxs
            .into_iter()
            .enumerate()
            .map(|(i, rx)| {
                let mut toks = Vec::new();
                for ev in rx {
                    match ev {
                        Event::Token(t) => toks.push(t),
                        Event::Done(_) => break,
                        Event::Error(e) => panic!("req {i} error: {e}"),
                    }
                }
                toks
            })
            .collect();
        handle.shutdown();
        let m = join.join().unwrap().unwrap();
        (streams, m)
    };
    let (clean_streams, _clean_m) = run(None);
    let (chaos_streams, chaos_m) = run(Some("exec:decode:at=3"));
    assert_eq!(chaos_m.faults_injected, 1);
    assert!(
        chaos_m.sched_preemptions >= 3,
        "containment must preempt the decoding slots, not fail them"
    );
    assert_eq!(
        clean_streams, chaos_streams,
        "re-prefilling from token history must reproduce the greedy \
         streams"
    );
    assert_eq!(chaos_m.n_requests, 3, "no request may be lost");
    assert_eq!(chaos_m.pages_used, 0);
}

/// Graceful drain: everything admitted before the drain finishes and
/// streams to completion; submissions after it are rejected with a
/// structured `overloaded` error; the drain call returns the report.
#[test]
fn drain_completes_inflight() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_drain.aockpt");
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    let mut rxs = Vec::new();
    for i in 0..4u64 {
        let (tx, rx) = channel();
        handle
            .submit(SubmitReq {
                id: i,
                prompt_tokens: vec![61 + i as u32; 4 + i as usize],
                max_new_tokens: 6,
                temperature: 0.0,
                seed: i,
                tx,
                submitted_at: Instant::now(),
                enqueued_at: None,
                resume: None,
                deadline: None,
            })
            .unwrap();
        rxs.push(rx);
    }
    // commands are FIFO: the drain lands after all four submissions
    let report = handle.drain().unwrap();
    assert!(report.contains("requests"), "{report}");
    for (i, rx) in rxs.into_iter().enumerate() {
        let mut done = false;
        for ev in rx {
            match ev {
                Event::Done(info) => {
                    assert_eq!(info.n_generated, 6, "req {i}");
                    done = true;
                }
                Event::Error(e) => panic!("req {i} error: {e}"),
                Event::Token(_) => {}
            }
        }
        assert!(done, "req {i} must finish before the drain completes");
    }
    // a draining engine sheds new load with an overloaded-class error
    let (tx, rx) = channel();
    handle
        .submit(SubmitReq {
            id: 9,
            prompt_tokens: vec![88; 4],
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 9,
            tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: None,
        })
        .unwrap();
    let mut rejected = false;
    for ev in rx {
        if let Event::Error(e) = ev {
            assert_eq!(e.kind, ErrorKind::Overloaded, "{e}");
            assert!(e.message.contains("draining"), "{e}");
            rejected = true;
            break;
        }
    }
    assert!(rejected, "submissions after drain must be rejected");
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert_eq!(m.n_requests, 4);
    assert_eq!(m.rejected_overload, 1);
}

/// Deadlines: an already-expired queued request is swept with a
/// `deadline` error before prefill; a decoding request whose deadline
/// passes finishes early with `finish_reason="deadline"`.
#[test]
fn deadlines_shed_queued_and_finish_decoding() {
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_deadline.aockpt");
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    // already expired at submit: the sweep rejects it before prefill
    let (tx, rx) = channel();
    handle
        .submit(SubmitReq {
            id: 0,
            prompt_tokens: vec![44; 4],
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 0,
            tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: Some(Instant::now()),
        })
        .unwrap();
    let mut swept = false;
    for ev in rx {
        if let Event::Error(e) = ev {
            assert_eq!(e.kind, ErrorKind::Deadline, "{e}");
            assert!(e.message.contains("queued"), "{e}");
            swept = true;
            break;
        }
    }
    assert!(swept, "expired queued request must be swept with an error");

    // a live request whose budget cannot cover the generation: the
    // deadline passes mid-decode and the slot finishes early. The
    // 40-token prompt lands in the s128 bucket, so the context cap is
    // ~88 decode steps away — far more XLA wall-clock than the 5ms
    // deadline on any host.
    let (tx, rx) = channel();
    handle
        .submit(SubmitReq {
            id: 1,
            prompt_tokens: vec![47; 40],
            max_new_tokens: 100_000,
            temperature: 0.0,
            seed: 1,
            tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: Some(
                Instant::now() + std::time::Duration::from_millis(5),
            ),
        })
        .unwrap();
    let mut finish = None;
    for ev in rx {
        match ev {
            Event::Done(info) => {
                finish = Some(info);
                break;
            }
            Event::Error(e) => panic!("error: {e}"),
            Event::Token(_) => {}
        }
    }
    handle.shutdown();
    let info = finish.expect("request never finished");
    assert_eq!(info.reason, FinishReason::Deadline);
    let m = join.join().unwrap().unwrap();
    assert_eq!(m.rejected_deadline, 1);
    assert_eq!(m.n_requests, 1);
}

/// Cancellation mid-generation: the request gets exactly one terminal
/// `canceled` error, its slot and pages are reclaimed, and the engine
/// keeps serving.
#[test]
fn cancel_releases_slot_and_pages() {
    let Some(dir) = artifacts_dir() else { return };
    let cache_scheme = CacheScheme::F32;
    if !has_paged_artifacts(&dir, cache_scheme) {
        return;
    }
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_cancel.aockpt");
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme,
        kv_layout: KvLayout::Paged,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    let (tx, rx) = channel();
    handle
        .submit(SubmitReq {
            id: 0,
            prompt_tokens: vec![52; 4],
            max_new_tokens: 100_000, // runs until canceled
            temperature: 0.0,
            seed: 0,
            tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: None,
        })
        .unwrap();
    // wait for generation to actually start, then cancel mid-stream
    let first = rx.recv().unwrap();
    assert!(matches!(first, Event::Token(_)), "{first:?}");
    handle.cancel(0);
    let mut canceled = false;
    for ev in rx {
        match ev {
            Event::Token(_) => {}
            Event::Error(e) => {
                assert_eq!(e.kind, ErrorKind::Canceled, "{e}");
                canceled = true;
                break;
            }
            ev => panic!("expected canceled error, got {ev:?}"),
        }
    }
    assert!(canceled, "cancel must deliver a terminal error event");
    // the slot and its pages are free again: fresh work completes
    let (tx, rx) = channel();
    handle
        .submit(SubmitReq {
            id: 1,
            prompt_tokens: vec![53; 4],
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 1,
            tx,
            submitted_at: Instant::now(),
            enqueued_at: None,
            resume: None,
            deadline: None,
        })
        .unwrap();
    let mut done = false;
    for ev in rx {
        if let Event::Done(info) = ev {
            assert_eq!(info.n_generated, 4);
            done = true;
        }
    }
    assert!(done);
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert_eq!(m.n_canceled, 1);
    assert_eq!(m.pages_used, 0, "canceled request must release its pages");
}

/// Regression (abandoned event stream): a client that disconnects after
/// the first token must cancel the request engine-side (releasing its
/// slot), the `shutdown` op must drain and answer, and a post-drain
/// client gets a typed `overloaded` error from `Client::generate`.
#[test]
fn server_disconnect_cancels_request() {
    use std::io::{BufRead, BufReader, Write};
    let Some(dir) = artifacts_dir() else { return };
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_srv.aockpt");
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir,
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    // grab a free port, then serve exactly three connections on it
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let server = {
        let handle = handle.clone();
        let addr = addr.clone();
        std::thread::spawn(move || {
            ao::coordinator::server::serve(
                &addr,
                handle,
                std::sync::Arc::new(Tokenizer::byte_level()),
                Some(3),
            )
        })
    };
    // conn 1: request a long generation, read ONE token line, hang up
    std::thread::sleep(std::time::Duration::from_millis(100));
    {
        let mut c = std::net::TcpStream::connect(&addr).unwrap();
        let req = "{\"prompt\": \"hello world\", \"max_new_tokens\": 100000}";
        writeln!(c, "{req}").unwrap();
        let mut line = String::new();
        BufReader::new(c.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(!line.contains("\"error\""), "{line}");
        c.shutdown(std::net::Shutdown::Both).unwrap();
    } // dropped mid-stream: the server's next write fails -> cancel
    std::thread::sleep(std::time::Duration::from_millis(100));
    // conn 2: admin shutdown -> graceful drain + final report
    {
        let mut c = std::net::TcpStream::connect(&addr).unwrap();
        let req = "{\"op\": \"shutdown\"}";
        writeln!(c, "{req}").unwrap();
        let mut line = String::new();
        BufReader::new(c.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("\"drained\""), "{line}");
    }
    // conn 3: the drained engine sheds load with a typed client error
    {
        let mut client =
            ao::coordinator::server::Client::connect(&addr).unwrap();
        let err = client
            .generate("more work", 4, 0.0)
            .expect_err("a draining server must reject new work");
        let kind = err
            .downcast_ref::<ao::coordinator::server::ServerError>()
            .map(|e| e.kind);
        assert_eq!(kind, Some(ErrorKind::Overloaded), "{err:#}");
    }
    server.join().unwrap().unwrap();
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert_eq!(
        m.n_canceled, 1,
        "the abandoned stream must cancel engine-side"
    );
    assert!(m.rejected_overload >= 1);
}

/// Live introspection: the `{"op": "stats"}` server op answers with a
/// `{"stats": {...}}` JSON snapshot without closing the connection, and
/// the snapshot's counters equal the engine's final report, under both
/// KV-cache schemes and with the tracer attached. Contract:
/// docs/observability.md.
#[test]
fn stats_op_roundtrip() {
    use ao::util::json::Value;
    use std::io::{BufRead, BufReader, Write};
    let Some(dir) = artifacts_dir() else { return };
    for cache_scheme in [CacheScheme::F32, CacheScheme::Int8] {
        if !has_admit_artifacts(&dir, cache_scheme) {
            return;
        }
        let master = tiny_master_ckpt(&dir);
        let tmp = std::env::temp_dir().join("ao_int_tests");
        std::fs::create_dir_all(&tmp).unwrap();
        let ckpt_path =
            tmp.join(format!("tiny_f32_stats_{}.aockpt", cache_scheme.tag()));
        master.save(&ckpt_path).unwrap();

        let (handle, join) = engine::spawn(engine::EngineConfig {
            artifacts_dir: dir.clone(),
            ckpt_path,
            model: "tiny".into(),
            scheme: "f32".into(),
            cache_scheme,
            kv_layout: KvLayout::Static,
            eos_token: None,
            host_admission: false,
            prefix_cache: false,
            max_batch_tokens: None,
            fault_retries: 3,
            fault_backoff_ms: 1,
            fault_plan: None,
            max_queue: None,
            default_deadline_ms: None,
            // stats must report the same numbers with the tracer attached
            trace: true,
            trace_capacity: 0,
            trace_out: None,
            fault_jitter_ms: 0,
            bounded_stats: false,
            metrics_out: None,
            postmortem_dir: None,
            slo_window_secs: 0,
            slo_windows: 0,
        });
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let server = {
            let handle = handle.clone();
            let addr = addr.clone();
            std::thread::spawn(move || {
                ao::coordinator::server::serve(
                    &addr,
                    handle,
                    std::sync::Arc::new(Tokenizer::byte_level()),
                    Some(2),
                )
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(100));
        // conn 1: a finished generation, so the counters are non-zero
        let gen = {
            let mut c =
                ao::coordinator::server::Client::connect(&addr).unwrap();
            c.generate("hello world", 8, 0.0).unwrap()
        };
        assert_eq!(gen.n_generated, 8, "{:?}", gen.reason);
        // conn 2: stats snapshot, then shutdown on the SAME connection --
        // introspection must not consume the connection's request budget
        let stats = {
            let mut c = std::net::TcpStream::connect(&addr).unwrap();
            let mut reader = BufReader::new(c.try_clone().unwrap());
            writeln!(c, "{{\"op\": \"stats\"}}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let reply = Value::parse(&line).expect("stats reply is JSON");
            writeln!(c, "{{\"op\": \"shutdown\"}}").unwrap();
            let mut bye = String::new();
            reader.read_line(&mut bye).unwrap();
            assert!(bye.contains("\"drained\""), "{bye}");
            reply.req("stats").expect("stats envelope").clone()
        };
        server.join().unwrap().unwrap();
        handle.shutdown();
        let m = join.join().unwrap().unwrap();
        // the snapshot was taken after the only request finished, so its
        // counters must equal the final report's
        assert_eq!(stats.req_str("label").unwrap(), "engine");
        assert_eq!(stats.req_usize("requests").unwrap(), m.n_requests);
        assert_eq!(stats.req_usize("out_tokens").unwrap(), m.n_output_tokens);
        assert_eq!(stats.req_usize("in_tokens").unwrap(), m.n_prompt_tokens);
        assert_eq!(stats.req_usize("decode_steps").unwrap(), m.decode_steps);
        let cache = stats.req("cache").unwrap();
        assert_eq!(cache.req_str("scheme").unwrap(), cache_scheme.tag());
        // and the same values appear in the human-readable text report
        let r = m.report("engine");
        assert!(r.contains(&format!("requests={}", m.n_requests)), "{r}");
        assert!(
            r.contains(&format!("out_tokens={}", m.n_output_tokens)),
            "{r}"
        );
    }
}

/// Minimal Prometheus text-format check shared by the metrics-op and
/// postmortem tests: every non-comment line must be
/// `name{labels} value` with a parseable, finite value, and every
/// sample must carry the per-engine label.
fn assert_prometheus_wellformed(text: &str) {
    let mut samples = 0usize;
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, value) =
            line.rsplit_once(' ').expect("sample line has a value");
        assert!(
            head.starts_with("ao_"),
            "metric outside the ao_ namespace: {line}"
        );
        assert!(
            head.contains("engine=\""),
            "sample missing the engine label: {line}"
        );
        let v: f64 = value.parse().unwrap_or_else(|_| {
            panic!("unparseable sample value in: {line}")
        });
        assert!(v.is_finite(), "non-finite sample value in: {line}");
        samples += 1;
    }
    assert!(samples > 0, "exposition carries no samples");
    for family in [
        "ao_requests_total",
        "ao_mem_resident_bytes",
        "ao_rolling_latency_seconds",
    ] {
        assert!(
            text.contains(&format!("# TYPE {family} ")),
            "missing # TYPE for {family}"
        );
    }
}

#[test]
fn metrics_op_exposes_prometheus() {
    use ao::util::json::Value;
    use std::io::{BufRead, BufReader, Write};
    let Some(dir) = artifacts_dir() else { return };
    if !has_admit_artifacts(&dir, CacheScheme::F32) {
        return;
    }
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_metrics_op.aockpt");
    master.save(&ckpt_path).unwrap();

    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir.clone(),
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: None,
        max_queue: None,
        default_deadline_ms: None,
        trace: false,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: None,
        slo_window_secs: 0,
        slo_windows: 0,
    });
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let server = {
        let handle = handle.clone();
        let addr = addr.clone();
        std::thread::spawn(move || {
            ao::coordinator::server::serve(
                &addr,
                handle,
                std::sync::Arc::new(Tokenizer::byte_level()),
                Some(2),
            )
        })
    };
    std::thread::sleep(std::time::Duration::from_millis(100));
    let gen = {
        let mut c =
            ao::coordinator::server::Client::connect(&addr).unwrap();
        c.generate("hello world", 8, 0.0).unwrap()
    };
    assert_eq!(gen.n_generated, 8, "{:?}", gen.reason);
    // metrics op, then shutdown on the SAME connection: like stats, the
    // scrape must not consume the connection's request budget
    let text = {
        let mut c = std::net::TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        writeln!(c, "{{\"op\": \"metrics\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let reply = Value::parse(&line).expect("metrics reply is JSON");
        writeln!(c, "{{\"op\": \"shutdown\"}}").unwrap();
        let mut bye = String::new();
        reader.read_line(&mut bye).unwrap();
        assert!(bye.contains("\"drained\""), "{bye}");
        reply.req_str("metrics").expect("metrics envelope").to_string()
    };
    server.join().unwrap().unwrap();
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert_prometheus_wellformed(&text);
    // the scrape was taken after the only request finished, so its
    // counters must equal the final report's
    assert!(
        text.contains(&format!(
            "ao_requests_total{{engine=\"engine\"}} {}",
            m.n_requests
        )),
        "{text}"
    );
    assert!(
        text.contains(&format!(
            "ao_output_tokens_total{{engine=\"engine\"}} {}",
            m.n_output_tokens
        )),
        "{text}"
    );
}

#[test]
fn chaos_postmortem_bundle_round_trips() {
    use ao::coordinator::trace::{check_spans, event_from_json};
    use ao::util::json::Value;
    let Some(dir) = artifacts_dir() else { return };
    if !has_admit_artifacts(&dir, CacheScheme::F32) {
        return;
    }
    let master = tiny_master_ckpt(&dir);
    let tmp = std::env::temp_dir().join("ao_int_tests");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("tiny_f32_postmortem.aockpt");
    master.save(&ckpt_path).unwrap();
    let bundle_dir = tmp.join("postmortem_chaos");
    let _ = std::fs::remove_dir_all(&bundle_dir);

    let plan = "exec:decode:every=5:n=2,transfer:h2d:every=7:n=2";
    let (handle, join) = engine::spawn(engine::EngineConfig {
        artifacts_dir: dir.clone(),
        ckpt_path,
        model: "tiny".into(),
        scheme: "f32".into(),
        cache_scheme: CacheScheme::F32,
        kv_layout: KvLayout::Static,
        eos_token: None,
        host_admission: false,
        prefix_cache: false,
        max_batch_tokens: None,
        fault_retries: 3,
        fault_backoff_ms: 1,
        fault_plan: Some(plan.into()),
        max_queue: None,
        default_deadline_ms: None,
        trace: true,
        trace_capacity: 0,
        trace_out: None,
        fault_jitter_ms: 0,
        bounded_stats: false,
        metrics_out: None,
        postmortem_dir: Some(bundle_dir.clone()),
        slo_window_secs: 0,
        slo_windows: 0,
    });
    let mut rxs = Vec::new();
    for i in 0..6u64 {
        let (tx, rx) = channel();
        handle
            .submit(SubmitReq {
                id: i,
                prompt_tokens: vec![25 + 3 * i as u32; 3 + (2 * i as usize) % 7],
                max_new_tokens: 6,
                temperature: 0.0,
                seed: i,
                tx,
                submitted_at: Instant::now(),
                enqueued_at: None,
                resume: None,
                deadline: None,
            })
            .unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let mut done = false;
        for ev in rx {
            if matches!(ev, Event::Done(_) | Event::Error(_)) {
                done = true;
                break;
            }
        }
        assert!(done, "request stream ended without a terminal event");
    }
    // operator dump: same writer the fatal path uses
    let outcome = handle.dump().unwrap();
    assert!(
        outcome.contains("postmortem bundle written"),
        "{outcome}"
    );
    handle.shutdown();
    let m = join.join().unwrap().unwrap();
    assert!(m.faults_injected > 0, "chaos plan never fired");
    assert!(m.faults_retried > 0, "no retries recorded");

    // report.json: reason + a parseable report_json snapshot taken at
    // dump time (after the last request, so counters match the final)
    let report_text =
        std::fs::read_to_string(bundle_dir.join("report.json")).unwrap();
    let report = Value::parse(&report_text).expect("report.json parses");
    assert!(
        report.req_str("reason").unwrap().contains("operator dump"),
        "{report_text}"
    );
    let snap = report.req("report").unwrap();
    assert_eq!(snap.req_usize("requests").unwrap(), m.n_requests);
    let mem = snap.req("mem").unwrap();
    let cat_sum: u64 = ["weights", "kv_pages", "scale_pages", "io", "trace"]
        .iter()
        .map(|c| mem.req_usize(c).unwrap() as u64)
        .sum();
    assert_eq!(
        cat_sum,
        mem.req_usize("total").unwrap() as u64,
        "ledger categories must sum to the total with no remainder"
    );

    // config.json: the resolved EngineConfig, chaos plan included
    let cfg_text =
        std::fs::read_to_string(bundle_dir.join("config.json")).unwrap();
    let cfg = Value::parse(&cfg_text).expect("config.json parses");
    assert_eq!(cfg.req_str("model").unwrap(), "tiny");
    assert_eq!(cfg.req_str("fault_plan").unwrap(), plan);

    // fault_plan.txt mirrors the armed plan verbatim
    let plan_text =
        std::fs::read_to_string(bundle_dir.join("fault_plan.txt")).unwrap();
    assert_eq!(plan_text, plan);

    // metrics.prom: a valid exposition snapshot
    let prom =
        std::fs::read_to_string(bundle_dir.join("metrics.prom")).unwrap();
    assert_prometheus_wellformed(&prom);

    // retries.jsonl: one parseable record per retained retry
    let retries =
        std::fs::read_to_string(bundle_dir.join("retries.jsonl")).unwrap();
    let n_retry_lines = retries
        .lines()
        .map(|l| {
            let r = Value::parse(l).expect("retry line parses");
            assert!(r.req_str("site").is_ok(), "{l}");
            assert!(r.req_usize("attempt").is_ok(), "{l}");
        })
        .count();
    assert!(n_retry_lines > 0, "chaos run retained no retry records");

    // trace.jsonl: meta header, then events that survive the
    // JSON -> TraceEvent -> check_spans round trip
    let trace_text =
        std::fs::read_to_string(bundle_dir.join("trace.jsonl")).unwrap();
    let mut events = Vec::new();
    for (i, line) in trace_text.lines().enumerate() {
        let v = Value::parse(line).expect("trace line parses");
        if i == 0 {
            assert_eq!(v.req_str("ev").unwrap(), "meta", "{line}");
            continue;
        }
        events.push(
            event_from_json(&v)
                .unwrap_or_else(|| panic!("unmappable trace line: {line}")),
        );
    }
    assert!(!events.is_empty(), "dumped trace is empty");
    check_spans(events.iter()).expect("dumped trace passes check_spans");

    // trace.chrome.json: loadable as a JSON array
    let chrome =
        std::fs::read_to_string(bundle_dir.join("trace.chrome.json"))
            .unwrap();
    match Value::parse(&chrome) {
        Ok(Value::Arr(evs)) => assert!(!evs.is_empty()),
        other => panic!("chrome dump is not a JSON array: {other:?}"),
    }
}
